//! Leader/worker coordination layer.
//!
//! XLA executables are thread-affine (the `xla` crate's PJRT handles are
//! not `Send`), so compute always runs on dedicated OS threads (or
//! processes) while the control plane — progress streaming, CSV sinks,
//! the CLI — consumes [`Event`]s from an mpsc channel. Deployment
//! shapes sharing that contract:
//!
//! * [`run_experiment_threaded`] — one compute thread drives the whole
//!   [`crate::fl::Experiment`]; the round scheduler (see
//!   `fl/scheduler.rs`) overlaps its codec plane with compute when
//!   `cfg.pipelined` is set.
//! * [`run_experiment_sharded`] — clients are split round-robin over
//!   `cfg.compute_shards` **shard workers**, each owning its own PJRT
//!   client, client subset and codec worker pool. Shards run the same
//!   scheduler over their slice of each round's participants and stream
//!   their finished [`RoundLane`]s into the coordinator's fan-in. The
//!   coordinator performs the **ordered reduction** (lanes sorted by
//!   round slot — exactly the single-thread aggregation order), applies
//!   FedAvg, and hands the broadcast delta back to every shard; shard 0
//!   evaluates the central model on its synced replica.
//! * [`serve`] / [`join_shard`] / [`run_experiment_processes`] — the
//!   same protocol with shards as **separate OS processes** over TCP
//!   (`fsfl shard-worker` is the CLI entry for the worker side).
//!
//! How shard traffic moves is the config's
//! [`TransportKind`](crate::fl::TransportKind): in-process typed mpsc
//! channels (the historical fast path), or the serialized wire protocol
//! of [`crate::net`] over loopback pipes or TCP. On a wire transport
//! every `ShardCmd`/`ShardMsg` crosses a real byte boundary — framed,
//! checksummed, length-prefixed — the coordinator *decodes the actual
//! transmitted bitstreams* before aggregating, and transfer bytes are
//! measured at the frame layer into [`RunLog::wire`] instead of being
//! estimated. Bidirectional setups additionally broadcast the APPLY as
//! the server's **downstream bitstream, encoded once per round** and
//! fanned out as bytes; every shard decodes those exact bytes back into
//! the identical dequantized delta.
//!
//! # Session plane (checkpoint / resume / elastic membership)
//!
//! When [`crate::fl::ExperimentConfig::session`] is set, the
//! coordinator collects every shard's round-boundary client state over
//! the wire `STATE` pair at the configured cadence and writes a
//! versioned, checksummed snapshot through [`crate::session`]. A killed
//! run resumes from its newest valid snapshot
//! ([`run_experiment_resumed`], `fsfl run --resume`) with byte-identical
//! remaining bitstreams and final [`RunLog`]. The same `STATE` machinery
//! powers **elastic shard membership** ([`ElasticPlan`]): at a round
//! boundary a shard can leave and a replacement join through the normal
//! INIT/READY handshake, and the shard set itself can **grow or shrink
//! N→M** — all client state is collected, leavers stop, newcomers join,
//! and every member is rehydrated under the recomputed round-robin
//! assignment, so each client's residuals, optimizer moments and
//! RNG/schedule positions land on the worker that now owns it. In the
//! [`serve`] shape, membership events are satisfied directly from the
//! TCP listener: an external autoscaler just starts more `fsfl
//! shard-worker` processes. Snapshots record the live assignment, so a
//! resume rebuilds the post-resize membership. Churn never changes
//! outputs.
//!
//! All shapes speak the *paper's* wire protocol: clients emit DeepCABAC
//! bitstreams, the server decodes exactly those bytes, and byte
//! accounting happens on the encoded streams — nothing is
//! short-circuited. Determinism invariant: for a fixed config,
//! bitstreams and `RunLog` round metrics are byte-identical across
//! shard counts, schedule modes, pool widths, transports, kill/resume
//! boundaries **and membership churn** (see `ARCHITECTURE.md`,
//! `tests/integration_parallel.rs`, `tests/integration_transport.rs`
//! and `tests/integration_session.rs`).

use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::data::{Batch, Dataset};
use crate::exec::WorkerPool;
use crate::fl::scheduler::{self, ScheduleMode};
use crate::fl::synth::{synth_eval, SyntheticPlane};
use crate::fl::{
    build_setup, evaluate_params, Client, ClientState, EvalReport, Experiment, ExperimentCompute,
    ExperimentConfig, OnShardLoss, ProtocolConfig, RoundLane, RoundPolicy, Server, TransportKind,
};
use crate::metrics::{MsgKind, RoundMetrics, RunLog, ScaleStats, ShardEvent, ShardEventKind, WireStats};
use crate::model::params::Delta;
use crate::model::{Group, Manifest, ParamSet};
use crate::net::wire::{self, CmdTag, MsgTag, StateCmd, StateInstall};
use crate::net::{loopback_pair, FrameSink, FrameSource, KindCounters, TcpTransport, Transport};
use crate::obs::{track, Obs};
use crate::runtime::{ModelRuntime, Runtime};
use crate::session::{ClientPager, SessionState, SessionStore};
use crate::supervise::{Backoff, Clock, MonotonicClock};

pub use crate::net::wire::ComputeSpec;

/// Poll granularity of supervised waits: how often a blocked control
/// loop wakes to send heartbeats, advance a scripted clock and check
/// deadlines. Wall-clock — but only as a wakeup, never as a timing
/// source (all deadlines read the [`Clock`]).
const SUP_POLL: Duration = Duration::from_millis(1);

/// A silent-but-connected shard is declared dead when it has not echoed
/// a heartbeat for this many heartbeat intervals while idle.
const LEASE_INTERVALS: u32 = 3;

/// Events streamed from the compute thread(s) to observers.
#[derive(Debug)]
pub enum Event {
    /// One round finished; carries its metrics.
    RoundDone(RoundMetrics),
    /// The experiment completed with this log.
    Finished(RunLog),
    /// The experiment failed (message is the rendered error chain).
    Failed(String),
}

/// Scripted round-boundary membership changes for elastic deployments.
///
/// * `replace`: each `(round, shard)` entry means: immediately before
///   round `round` starts, shard `shard` leaves (its client state is
///   collected over the wire first) and a freshly provisioned worker
///   re-joins under the same index through the ordinary INIT/READY
///   handshake, then is rehydrated with the migrated state.
/// * `resize`: each `(round, shards)` entry means: immediately before
///   round `round` starts, the shard set is resized N→M. All client
///   state is collected, departing shards (on shrink) are stopped,
///   newcomers (on grow) are admitted under the new count, and every
///   member is rehydrated with the recomputed round-robin assignment —
///   residuals, optimizer moments, RNG and schedule positions land on
///   the worker that now owns each client.
///
/// Events at the same round boundary are processed replacements-first.
/// Outputs are byte-identical to the static-membership run for any
/// churn script, including N→M→N cycles (pinned by
/// `tests/integration_session.rs`).
#[derive(Debug, Clone, Default)]
pub struct ElasticPlan {
    /// `(round, shard)` replacement events, processed in order.
    pub replace: Vec<(usize, usize)>,
    /// `(round, new shard count)` resize events, processed in order
    /// (after any replacement at the same round).
    pub resize: Vec<(usize, usize)>,
}

/// One scripted membership event (see [`ElasticPlan`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ElasticEvent {
    /// Replace the shard at this index with a fresh worker.
    Replace(usize),
    /// Resize the shard set to this count.
    Resize(usize),
}

impl ElasticPlan {
    /// Whether the plan schedules no membership change at all.
    pub fn is_empty(&self) -> bool {
        self.replace.is_empty() && self.resize.is_empty()
    }

    /// Every event as `(round, event)`, sorted by round with
    /// replacements before resizes at the same boundary (stable within
    /// each kind, preserving listed order).
    fn timeline(&self) -> Vec<(usize, ElasticEvent)> {
        let mut ev: Vec<(usize, ElasticEvent)> = self
            .replace
            .iter()
            .map(|&(r, s)| (r, ElasticEvent::Replace(s)))
            .collect();
        ev.extend(self.resize.iter().map(|&(r, m)| (r, ElasticEvent::Resize(m))));
        ev.sort_by_key(|&(r, e)| (r, matches!(e, ElasticEvent::Resize(_))));
        ev
    }

    /// The last round any event is scheduled at (`None` when empty).
    fn last_event_round(&self) -> Option<usize> {
        self.replace
            .iter()
            .chain(self.resize.iter())
            .map(|&(r, _)| r)
            .max()
    }

    /// How many distinct worker admissions a run starting at `shards`
    /// needs under this plan (each replacement and each grown slot
    /// consumes one) — the surplus the multi-process launcher
    /// pre-spawns beyond the starting set.
    fn admissions(&self, shards: usize) -> usize {
        let mut cur = shards;
        let mut extra = 0usize;
        for (_, ev) in self.timeline() {
            match ev {
                ElasticEvent::Replace(_) => extra += 1,
                ElasticEvent::Resize(m) => {
                    extra += m.saturating_sub(cur);
                    cur = m;
                }
            }
        }
        extra
    }
}

/// The compute-shard count a config actually resolves to (never more
/// shards than clients, never less than one).
pub fn resolved_shards(cfg: &ExperimentConfig) -> usize {
    cfg.compute_shards.min(cfg.clients).max(1)
}

/// The shard count a (possibly resumed) session starts with: the
/// snapshot's live assignment when resuming — after an elastic resize
/// it legitimately differs from the config's `compute_shards` — or the
/// config's resolved count for a fresh run.
fn session_shards(cfg: &ExperimentConfig, resume: Option<&SessionState>) -> usize {
    match resume {
        Some(st) => st.shards.min(cfg.clients).max(1),
        None => resolved_shards(cfg),
    }
}

/// Run an experiment on dedicated compute thread(s), streaming per-round
/// events to `on_event` on the calling thread. Returns the final
/// [`RunLog`]. Dispatches to [`run_experiment_sharded`] when the config
/// asks for more than one compute shard, a wire transport, or a durable
/// session (checkpointing lives in the sharded coordinator).
pub fn run_experiment_threaded(
    cfg: ExperimentConfig,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_experiment_threaded_observed(cfg, None, &mut on_event)
}

/// [`run_experiment_threaded`] with an attached telemetry handle
/// (`fsfl run --trace-out` / `--metrics-addr`). Telemetry is strictly
/// passive: every output is byte-identical to the unobserved run.
pub fn run_experiment_threaded_observed(
    cfg: ExperimentConfig,
    obs: Obs,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    if resolved_shards(&cfg) > 1 || cfg.transport.is_wire() || cfg.session.is_some() {
        return run_sharded_impl(
            cfg,
            ComputeSpec::Real,
            ElasticPlan::default(),
            None,
            obs,
            on_event,
        );
    }
    run_single_thread(cfg, obs, on_event)
}

/// The single-compute-thread launcher body.
fn run_single_thread(
    cfg: ExperimentConfig,
    obs: Obs,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    let (tx, rx) = mpsc::channel::<Event>();
    let handle = std::thread::spawn(move || {
        let run = || -> Result<RunLog> {
            let rt = Runtime::cpu()?;
            let mut exp = Experiment::build(&rt, cfg)?;
            if let Some(t) = obs {
                exp.set_telemetry(t);
            }
            let tx2 = tx.clone();
            let log = exp.run_with(move |m| {
                let _ = tx2.send(Event::RoundDone(m.clone()));
            })?;
            Ok(log)
        };
        match run() {
            Ok(log) => {
                let _ = tx.send(Event::Finished(log));
            }
            Err(e) => {
                let msg = format!("{e:#}");
                // If the receiver is gone the failure would vanish
                // silently — at least leave it on stderr.
                if tx.send(Event::Failed(msg.clone())).is_err() {
                    eprintln!("compute thread failed with no listener: {msg}");
                }
            }
        }
    });

    let mut result: Option<RunLog> = None;
    for ev in rx {
        on_event(&ev);
        match ev {
            Event::Finished(log) => {
                result = Some(log);
                break;
            }
            Event::Failed(msg) => {
                let _ = handle.join();
                return Err(anyhow::anyhow!(msg));
            }
            Event::RoundDone(_) => {}
        }
    }
    handle
        .join()
        .map_err(|_| anyhow::anyhow!("compute thread panicked"))?;
    result.ok_or_else(|| anyhow::anyhow!("experiment ended without result"))
}

/// Synchronous convenience wrapper (shares one [`Runtime`] across calls —
/// used by harnesses that sweep many configs). Always single-shard: the
/// caller owns the runtime's thread.
pub fn run_experiment(rt: &Runtime, cfg: ExperimentConfig) -> Result<RunLog> {
    let mut exp = Experiment::build(rt, cfg)?;
    exp.run()
}

// ---------------------------------------------------------------------------
// Sharded deployment
// ---------------------------------------------------------------------------

/// Shard → coordinator messages (all shards share one fan-in channel).
/// On a wire transport these cross as serialized frames (`net::wire`
/// tags `READY`/`ROUND_DONE`/`EVAL`/`STATE`/`FAILED`); per-connection
/// reader threads decode them back into this enum, so the control loop
/// is transport-oblivious. `ConnDown` is coordinator-local: a reader
/// reporting that its connection died, tagged with the connection
/// generation so a deliberately-departed shard's close is told apart
/// from a live shard's failure.
enum ShardMsg {
    /// Shard built its runtime + client subset; carries the initial
    /// model so the coordinator can construct the server without a
    /// runtime (or artifacts directory) of its own.
    Ready { shard: usize, init: ParamSet },
    /// One round's finished lanes, each tagged with its global slot.
    RoundDone {
        shard: usize,
        lanes: Vec<(usize, RoundLane)>,
    },
    /// Central-model evaluation after broadcast (shard 0 only).
    Eval {
        report: EvalReport,
        scale_stats: Vec<ScaleStats>,
    },
    /// Collected client states (session plane: checkpoint / migration).
    State {
        shard: usize,
        clients: Vec<ClientState>,
    },
    /// Fatal shard error (rendered error chain).
    Failed { shard: usize, msg: String },
    /// Heartbeat echo: the shard acknowledges the coordinator's probe,
    /// returning its nonce (liveness lease renewal + recovery barrier).
    Heartbeat { shard: usize, nonce: u64 },
    /// A wire connection closed or corrupted (reader-local; `conn` is
    /// the connection generation, so stale reports from replaced shards
    /// are ignored).
    // fsfl-lint: allow(wire-corpus): coordinator-local failure signal, never serialized onto the wire
    ConnDown {
        conn: u64,
        shard: usize,
        msg: String,
    },
}

/// Coordinator → shard commands (one channel/connection per shard). On
/// a wire transport these cross as serialized frames (`net::wire` tags
/// `ROUND`/`APPLY`/`STATE`/`STOP`; lane recycling stays local to each
/// side, so `Apply`'s lanes never travel).
enum ShardCmd {
    /// Run the round over these `(global slot, client id)` assignments
    /// (possibly empty — the shard still participates in the barrier).
    Round { slots: Vec<(usize, usize)> },
    /// Apply the aggregated broadcast to every local replica, take the
    /// round's lanes back for recycling, and — when `eval` — evaluate
    /// the central model on the synced replica. In bidirectional wire
    /// modes `stream` carries the server's once-encoded downstream
    /// bitstream; those exact bytes fan out instead of the dense delta.
    Apply {
        broadcast: Arc<Delta>,
        stream: Option<Arc<Vec<u8>>>,
        lanes: Vec<(usize, RoundLane)>,
        eval: bool,
    },
    /// Session plane: install replica/client state and/or collect it.
    State(StateCmd),
    /// Liveness probe: the shard echoes the nonce back as
    /// [`ShardMsg::Heartbeat`] as soon as it next reads its command
    /// channel. A monotonically increasing nonce doubles as the
    /// recovery barrier: once a shard echoes nonce N, FIFO ordering
    /// guarantees no message it sent before receiving N is still in
    /// flight.
    Heartbeat { nonce: u64 },
    /// Shut down cleanly.
    Stop,
}

/// Coordinator-side state shared by every wire [`ShardTx`] and reader:
/// the recycled lane pool, and the once-per-round encoded APPLY
/// payload (the broadcast delta is model-sized, so serializing it once
/// and fanning the bytes out beats re-encoding it per shard N×).
struct WireShared {
    /// Lane recycling: readers pop on ROUND_DONE decode, `Apply` sends
    /// push back.
    pool: Mutex<Vec<RoundLane>>,
    /// Cached APPLY payload for the current round (encoded with
    /// `eval = false`; the flag byte is patched per send). Any ROUND
    /// send marks it stale, so the cache can never leak a previous
    /// round's broadcast even though the `Arc<Delta>` buffer recycles.
    apply: Mutex<ApplyCache>,
}

#[derive(Default)]
struct ApplyCache {
    buf: Vec<u8>,
    fresh: bool,
}

/// Coordinator-side sender for one shard: typed channel (mpsc) or a
/// framed wire sink. Wire sends serialize through recycled buffers;
/// `Apply` lanes are returned to the shared coordinator-side lane pool
/// instead of crossing the transport.
enum ShardTx {
    Mpsc(mpsc::Sender<ShardCmd>),
    Wire {
        sink: FrameSink,
        shared: Arc<WireShared>,
        buf: Vec<u8>,
    },
}

/// Byte offset of the eval flag inside an APPLY payload (tag, then
/// bool) — patched per shard over the shared once-encoded broadcast.
const APPLY_EVAL_OFFSET: usize = 1;

impl ShardTx {
    fn send(&mut self, cmd: ShardCmd) -> Result<()> {
        match self {
            ShardTx::Mpsc(tx) => tx
                .send(cmd)
                .map_err(|_| anyhow!("shard channel closed")),
            ShardTx::Wire { sink, shared, buf } => match cmd {
                ShardCmd::Round { slots } => {
                    wire::encode_round(buf, &slots);
                    if let Ok(mut cache) = shared.apply.lock() {
                        cache.fresh = false;
                    }
                    sink.send(buf)
                }
                ShardCmd::Apply {
                    broadcast,
                    stream,
                    lanes,
                    eval,
                } => {
                    if let Ok(mut free) = shared.pool.lock() {
                        free.extend(lanes.into_iter().map(|(_, l)| l));
                    }
                    let mut cache = shared
                        .apply
                        .lock()
                        .map_err(|_| anyhow!("apply cache poisoned"))?;
                    if !cache.fresh {
                        match &stream {
                            Some(s) => wire::encode_apply_stream(&mut cache.buf, s, false),
                            None => wire::encode_apply(&mut cache.buf, &broadcast, false),
                        }
                        cache.fresh = true;
                    }
                    if eval {
                        // Patch-and-restore under the lock: payloads are
                        // identical across shards except this one byte,
                        // and the frame checksum is computed per send.
                        cache.buf[APPLY_EVAL_OFFSET] = 1;
                        let sent = sink.send(&cache.buf);
                        cache.buf[APPLY_EVAL_OFFSET] = 0;
                        sent
                    } else {
                        sink.send(&cache.buf)
                    }
                }
                ShardCmd::State(state) => {
                    wire::encode_state_cmd(buf, &state);
                    sink.send(buf)
                }
                ShardCmd::Heartbeat { nonce } => {
                    wire::encode_heartbeat_cmd(buf, nonce);
                    sink.send(buf)
                }
                ShardCmd::Stop => {
                    wire::encode_stop(buf);
                    sink.send(buf)
                }
            },
        }
    }
}

// ---------------------------------------------------------------------------
// Session context + worker admission
// ---------------------------------------------------------------------------

/// Where a scripted chaos death strikes its shard worker (fault
/// injection for the recovery conformance tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosPoint {
    /// Die silently upon *receiving* the ROUND command for the scripted
    /// round — mid-round, before any lane is returned.
    MidRound,
    /// Die silently upon receiving the first collecting STATE command
    /// after completing the scripted round (mid-checkpoint-collect).
    MidCollect,
    /// Stop serving upon receiving the scripted round's ROUND command
    /// but keep the connection open and keep draining commands — a
    /// silent straggler, detectable only by deadline/lease expiry.
    Stall,
}

/// One scripted shard death: worker `shard` dies at `point` of round
/// `round`. Consumed by the *first* admission of that shard index, so a
/// respawned replacement runs clean. "Silently" means no FAILED
/// message: the coordinator must notice via connection teardown,
/// deadline or lease — exactly like a `kill -9`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosDeath {
    /// Which shard index dies.
    pub shard: usize,
    /// The (0-based) round whose command triggers the death. The
    /// scripted worker counts the ROUND commands it receives, so the
    /// trigger is exact as long as the chaos shard is the round's first
    /// casualty (single-fault injection).
    pub round: usize,
    /// Where within the round it dies.
    pub point: ChaosPoint,
}

/// Everything session-related the control loop needs: the snapshot
/// store + cadence, an optional resume state, the scripted membership
/// plan, crash/chaos injection and the time source for supervised
/// waits.
struct SessionCtx {
    store: Option<SessionStore>,
    every: usize,
    crash_after: Option<usize>,
    resume: Option<SessionState>,
    plan: ElasticPlan,
    synthetic: bool,
    /// Time source for heartbeats, deadlines and backoff sleeps —
    /// monotonic in production, scripted in the chaos tests.
    clock: Arc<dyn Clock>,
    /// Scripted shard deaths, handed to workers at admission.
    chaos: Vec<ChaosDeath>,
    /// Telemetry handle (strictly passive; `None` keeps every
    /// instrumentation site a single branch).
    obs: Obs,
}

impl SessionCtx {
    fn build(
        cfg: &ExperimentConfig,
        compute: &ComputeSpec,
        plan: ElasticPlan,
        resume: Option<SessionState>,
    ) -> Result<Self> {
        let store = match &cfg.session {
            Some(s) => {
                // The checkpoint dir crosses the config codec (INIT
                // handshakes and every snapshot embed the config), which
                // is UTF-8; a lossily-encoded dir would silently redirect
                // the *resumed* run's checkpoints elsewhere.
                if s.dir.to_str().is_none() {
                    return Err(anyhow!(
                        "checkpoint dir {:?} is not valid UTF-8 and cannot cross the \
                         config codec (snapshots embed the experiment config)",
                        s.dir
                    ));
                }
                Some(SessionStore::open(&s.dir)?.with_retain(s.retain))
            }
            None => None,
        };
        Ok(Self {
            store,
            every: cfg.session.as_ref().map(|s| s.every).unwrap_or(0),
            crash_after: cfg.session.as_ref().and_then(|s| s.crash_after),
            resume,
            plan,
            synthetic: matches!(compute, ComputeSpec::Synthetic { .. }),
            clock: Arc::new(MonotonicClock::new()),
            chaos: Vec::new(),
            obs: None,
        })
    }
}

/// How the control loop provisions a replacement shard worker at a
/// membership boundary. Each deployment shape brings its own
/// implementation (spawn a thread, open a loopback pair, connect a TCP
/// worker); `NoAdmit` is the shape that cannot (externally-joined
/// workers must reconnect on their own).
trait Admit {
    /// Provision one worker for `shard` (of `shards`), returning its
    /// connection generation and sender. The worker introduces itself
    /// with READY over the shared fan-in channel.
    fn admit(&mut self, shard: usize, shards: usize) -> Result<(u64, ShardTx)>;

    /// Release any retained fan-in sender once no further admission can
    /// happen, so channel disconnection (every worker gone without a
    /// message) still fails the control loop fast. Idempotent.
    fn seal(&mut self) {}
}

/// [`Admit`] for deployments that cannot provision workers themselves.
struct NoAdmit;

impl Admit for NoAdmit {
    fn admit(&mut self, shard: usize, _shards: usize) -> Result<(u64, ShardTx)> {
        Err(anyhow!(
            "cannot provision a replacement for shard {shard}: this deployment's workers \
             join externally (start a new `fsfl shard-worker` and re-serve)"
        ))
    }
}

/// [`Admit`] over in-process mpsc shard threads.
struct MpscAdmit {
    cfg: ExperimentConfig,
    compute: ComputeSpec,
    /// Fan-in sender handed to every spawned shard. Dropped via
    /// [`MpscAdmit::seal`] once no further admissions can happen, so
    /// `msg_rx.recv()` still disconnects (and the control loop still
    /// fails fast) when every shard exits without a message.
    msg_tx: Option<mpsc::Sender<ShardMsg>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    next_conn: u64,
    /// Scripted deaths, consumed by the first admission of their shard.
    chaos: Vec<ChaosDeath>,
    /// Telemetry handle cloned into every admitted shard thread (mpsc
    /// shards run in-process, so their codec stages can be traced).
    obs: Obs,
}

impl Admit for MpscAdmit {
    fn seal(&mut self) {
        self.msg_tx = None;
    }

    fn admit(&mut self, shard: usize, shards: usize) -> Result<(u64, ShardTx)> {
        let (cmd_tx, cmd_rx) = mpsc::channel::<ShardCmd>();
        let cfg = self.cfg.clone();
        let compute = self.compute.clone();
        let tx = self
            .msg_tx
            .as_ref()
            .ok_or_else(|| anyhow!("admission channel sealed (static membership)"))?
            .clone();
        let chaos = take_chaos(&mut self.chaos, shard);
        // Under supervision a thread's exit must be *observable* (an
        // mpsc worker has no reader thread to report EOF); the guard
        // posts ConnDown on any exit, and staleness filtering discards
        // it for deliberate departures.
        let guard = cfg.policy.supervised();
        self.next_conn += 1;
        let conn = self.next_conn;
        let obs = self.obs.clone();
        self.handles.push(std::thread::spawn(move || {
            shard_thread_mpsc(cfg, compute, shard, shards, conn, guard, chaos, obs, cmd_rx, tx)
        }));
        Ok((conn, ShardTx::Mpsc(cmd_tx)))
    }
}

/// Pop the scripted death for `shard`, if one is still pending.
fn take_chaos(chaos: &mut Vec<ChaosDeath>, shard: usize) -> Option<ChaosDeath> {
    chaos
        .iter()
        .position(|c| c.shard == shard)
        .map(|i| chaos.swap_remove(i))
}

/// How a [`WireAdmit`] provisions brand-new worker endpoints.
enum WireMode<'a> {
    /// In-process loopback byte pipes.
    Loopback,
    /// Localhost TCP through this listener (worker threads connect in).
    Tcp { listener: TcpListener },
    /// Accept an externally-launched worker from this listener without
    /// provisioning anything — the [`serve`] shape, where an autoscaler
    /// (or a human) starts `fsfl shard-worker` processes and the
    /// coordinator admits whoever connects. Workers that connect before
    /// a membership boundary simply wait in the accept backlog. The
    /// caller's `liveness` poll runs while an accept blocks (initial
    /// joins *and* mid-run membership admissions), so a dead worker
    /// fails the join fast instead of burning the whole accept timeout.
    Accept {
        listener: TcpListener,
        liveness: Box<dyn FnMut() -> Result<()> + 'a>,
    },
}

/// Wire-connection bookkeeping shared by every wire deployment shape:
/// INIT handshakes, per-connection reader threads, byte counters, and
/// (when a [`WireMode`] is present) provisioning of replacement
/// workers.
struct WireAdmit<'a> {
    cfg: ExperimentConfig,
    compute: ComputeSpec,
    /// Fan-in sender cloned into every reader thread. Dropped via
    /// [`WireAdmit::seal`] once no further admissions can happen, so
    /// `msg_rx.recv()` still disconnects when every reader exits
    /// without reporting.
    msg_tx: Option<mpsc::Sender<ShardMsg>>,
    shared: Arc<WireShared>,
    mode: Option<WireMode<'a>>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    readers: Vec<std::thread::JoinHandle<()>>,
    sent: Vec<Arc<KindCounters>>,
    received: Vec<Arc<KindCounters>>,
    next_conn: u64,
    /// Scripted deaths, consumed by the first admission of their shard.
    chaos: Vec<ChaosDeath>,
    /// Telemetry handle; attached endpoints get frame-level spans and
    /// register their counters with the live registry.
    obs: Obs,
    /// Supervision clock driving the join deadline in [`accept_one`]
    /// (the session's clock, so scripted tests control join expiry).
    clock: Arc<dyn Clock>,
}

impl<'a> WireAdmit<'a> {
    fn new(
        cfg: &ExperimentConfig,
        compute: &ComputeSpec,
        msg_tx: mpsc::Sender<ShardMsg>,
        mode: Option<WireMode<'a>>,
        clock: Arc<dyn Clock>,
    ) -> Self {
        Self {
            cfg: cfg.clone(),
            compute: compute.clone(),
            msg_tx: Some(msg_tx),
            shared: Arc::new(WireShared {
                pool: Mutex::new(Vec::new()),
                apply: Mutex::new(ApplyCache::default()),
            }),
            mode,
            workers: Vec::new(),
            readers: Vec::new(),
            sent: Vec::new(),
            received: Vec::new(),
            next_conn: 0,
            chaos: Vec::new(),
            obs: None,
            clock,
        }
    }

    /// INIT an established connection as `shard` and start its reader.
    fn attach(
        &mut self,
        shard: usize,
        shards: usize,
        conn: Box<dyn Transport>,
    ) -> Result<(u64, ShardTx)> {
        let (mut sink, mut source) = conn.open()?;
        if let Some(t) = &self.obs {
            sink.set_telemetry(t.clone());
            source.set_telemetry(t.clone());
            t.metrics.register_wire(sink.counter(), source.counter());
        }
        let mut buf = Vec::new();
        wire::encode_init(&mut buf, shard, shards, &self.cfg, &self.compute);
        sink.send(&buf)
            .map_err(|e| anyhow!("shard {shard}: INIT send failed: {e:#}"))?;
        self.sent.push(sink.counter());
        self.received.push(source.counter());
        self.next_conn += 1;
        let conn_id = self.next_conn;
        let tx = self
            .msg_tx
            .as_ref()
            .ok_or_else(|| anyhow!("admission channel sealed (static membership)"))?
            .clone();
        let shared = self.shared.clone();
        self.readers.push(std::thread::spawn(move || {
            reader_loop(conn_id, shard, source, shared, tx)
        }));
        Ok((
            conn_id,
            ShardTx::Wire {
                sink,
                shared: self.shared.clone(),
                buf: Vec::new(),
            },
        ))
    }

    /// Arm the kernel-level read deadline on a coordinator-side TCP
    /// stream when heartbeats are on: a transport-layer backstop under
    /// the clock-driven lease. Generous by design — it must outlast a
    /// whole round of compute plus the configured deadlines, so it only
    /// catches connections that are truly wedged.
    fn arm_deadline(&self, t: TcpTransport) -> Result<Box<dyn Transport>> {
        let p = &self.cfg.policy;
        if !p.heartbeat.is_zero() {
            let backstop = (p.heartbeat * 4 + p.round_deadline * 2).max(Duration::from_secs(5));
            t.set_read_deadline(Some(backstop))?;
        }
        Ok(Box::new(t))
    }

    /// Total frame-layer traffic across every connection ever attached,
    /// broken down by message kind.
    fn wire_stats(&self) -> WireStats {
        let mut stats = WireStats::default();
        for c in &self.sent {
            let s = c.snapshot();
            for k in 0..MsgKind::COUNT {
                stats.sent_by_kind[k] += s[k];
            }
        }
        for c in &self.received {
            let r = c.snapshot();
            for k in 0..MsgKind::COUNT {
                stats.received_by_kind[k] += r[k];
            }
        }
        stats
    }

    /// Join every reader and worker thread (teardown).
    fn join_all(&mut self) {
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Admit for WireAdmit<'_> {
    fn seal(&mut self) {
        self.msg_tx = None;
    }

    fn admit(&mut self, shard: usize, shards: usize) -> Result<(u64, ShardTx)> {
        enum Plan {
            None,
            Loopback,
            /// Spawn an in-process worker thread that connects in.
            Tcp(std::net::SocketAddr),
            /// Accept an externally-launched worker; spawn nothing.
            Accept,
        }
        let plan = match &self.mode {
            None => Plan::None,
            Some(WireMode::Loopback) => Plan::Loopback,
            Some(WireMode::Tcp { listener }) => Plan::Tcp(
                listener
                    .local_addr()
                    .map_err(|e| anyhow!("listener address: {e}"))?,
            ),
            Some(WireMode::Accept { .. }) => Plan::Accept,
        };
        let join_timeout = self.cfg.policy.join_timeout;
        let clock = self.clock.clone();
        let chaos = take_chaos(&mut self.chaos, shard);
        let conn: Box<dyn Transport> = match plan {
            Plan::None => {
                return NoAdmit.admit(shard, shards);
            }
            Plan::Loopback => {
                let (coord_end, shard_end) = loopback_pair();
                // Tree fan-in: spawn a mid-tier aggregator instead of a
                // flat leaf worker; it fans its own subtree out over
                // internal loopback pipes (see serve_aggregator_transport).
                let tree = self.cfg.tree_children;
                self.workers.push(std::thread::spawn(move || {
                    if tree > 0 {
                        serve_aggregator_transport(Box::new(shard_end), tree)
                    } else {
                        serve_shard_transport_with(Box::new(shard_end), chaos)
                    }
                }));
                Box::new(coord_end)
            }
            Plan::Tcp(addr) => {
                let tree = self.cfg.tree_children;
                self.workers.push(std::thread::spawn(move || {
                    if tree > 0 {
                        serve_aggregator_transport(Box::new(TcpTransport::connect(addr)?), tree)
                    } else {
                        serve_shard_transport_with(Box::new(TcpTransport::connect(addr)?), chaos)
                    }
                }));
                let stream = match &self.mode {
                    Some(WireMode::Tcp { listener }) => {
                        accept_one(listener, join_timeout, &*clock, || Ok(()))?
                    }
                    _ => unreachable!("plan was Tcp"),
                };
                self.arm_deadline(TcpTransport::new(stream))?
            }
            Plan::Accept => {
                let stream = match &mut self.mode {
                    Some(WireMode::Accept { listener, liveness }) => {
                        accept_one(listener, join_timeout, &*clock, &mut **liveness)?
                    }
                    _ => unreachable!("plan was Accept"),
                };
                self.arm_deadline(TcpTransport::new(stream))?
            }
        };
        self.attach(shard, shards, conn)
    }
}

// ---------------------------------------------------------------------------
// Public deployment entry points
// ---------------------------------------------------------------------------

/// Run an experiment with clients sharded over `cfg.compute_shards`
/// compute workers (one PJRT client per shard) over the config's
/// transport. Streams the same [`Event`]s as [`run_experiment_threaded`]
/// and returns the final [`RunLog`]; outputs are byte-identical to the
/// single-thread path for any shard count and transport.
pub fn run_experiment_sharded(
    cfg: ExperimentConfig,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_sharded_impl(
        cfg,
        ComputeSpec::Real,
        ElasticPlan::default(),
        None,
        None,
        &mut on_event,
    )
}

/// [`run_experiment_sharded`] with a scripted [`ElasticPlan`]: shards
/// leave and replacements re-join, and the shard set grows/shrinks, at
/// the planned round boundaries, with client state migrating over the
/// wire. Outputs stay byte-identical to the static-membership run.
pub fn run_experiment_sharded_elastic(
    cfg: ExperimentConfig,
    plan: ElasticPlan,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_sharded_impl(cfg, ComputeSpec::Real, plan, None, None, &mut on_event)
}

/// [`run_experiment_sharded_elastic`] with an attached telemetry
/// handle (see [`run_experiment_threaded_observed`]).
pub fn run_experiment_sharded_elastic_observed(
    cfg: ExperimentConfig,
    plan: ElasticPlan,
    obs: Obs,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    run_sharded_impl(cfg, ComputeSpec::Real, plan, None, obs, on_event)
}

/// Resume a previously-checkpointed experiment on real compute from a
/// loaded [`SessionState`] (see `crate::session`; `fsfl run --resume`).
/// The passed `cfg` must equal the snapshot's config.
pub fn run_experiment_resumed(
    cfg: ExperimentConfig,
    state: SessionState,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_experiment_resumed_observed(cfg, state, None, &mut on_event)
}

/// [`run_experiment_resumed`] with an attached telemetry handle (see
/// [`run_experiment_threaded_observed`]).
pub fn run_experiment_resumed_observed(
    cfg: ExperimentConfig,
    state: SessionState,
    obs: Obs,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    run_sharded_impl(
        cfg,
        ComputeSpec::Real,
        ElasticPlan::default(),
        Some(state),
        obs,
        on_event,
    )
}

/// [`run_experiment_sharded`] over the deterministic synthetic compute
/// plane ([`crate::fl::SyntheticPlane`] on `manifest`) instead of real
/// PJRT clients. This is the transport/session test harness: it
/// exercises the full coordinator protocol — fan-out, wire
/// serialization, ordered fan-in, FedAvg, broadcast, eval barrier,
/// checkpoints — with no XLA backend and no artifacts, so the
/// differential conformance and multi-process CI tests run everywhere.
pub fn run_experiment_synthetic(
    cfg: ExperimentConfig,
    manifest: Arc<Manifest>,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_sharded_impl(
        cfg,
        ComputeSpec::Synthetic { manifest },
        ElasticPlan::default(),
        None,
        None,
        &mut on_event,
    )
}

/// [`run_experiment_synthetic`] with full session control: a scripted
/// membership plan and/or a resume state. This is the entry the session
/// conformance tests and `fsfl run --synth` / `--resume` use.
pub fn run_experiment_synthetic_session(
    cfg: ExperimentConfig,
    manifest: Arc<Manifest>,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_experiment_synthetic_supervised(cfg, manifest, plan, resume, None, Vec::new(), on_event)
}

/// [`run_experiment_synthetic_session`] with an injected [`Clock`] and
/// an attached telemetry handle. The golden-trace tests drive this with
/// a zero-tick scripted clock so every exported span timestamp is
/// deterministic; `fsfl run --synth --trace-out` drives it with the
/// monotonic clock.
#[allow(clippy::too_many_arguments)]
pub fn run_experiment_synthetic_session_observed(
    cfg: ExperimentConfig,
    manifest: Arc<Manifest>,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    clock: Option<Arc<dyn Clock>>,
    obs: Obs,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_synthetic_impl(
        cfg,
        manifest,
        plan,
        resume,
        clock,
        Vec::new(),
        obs,
        &mut on_event,
    )
}

/// [`run_experiment_synthetic_session`] with the supervision test
/// hooks: an injected [`Clock`] (scripted in the chaos tests, so no
/// deadline ever sleeps on wall time) and scripted [`ChaosDeath`]s.
/// Passing `None`/empty is exactly [`run_experiment_synthetic_session`].
#[allow(clippy::too_many_arguments)]
pub fn run_experiment_synthetic_supervised(
    cfg: ExperimentConfig,
    manifest: Arc<Manifest>,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    clock: Option<Arc<dyn Clock>>,
    chaos: Vec<ChaosDeath>,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_synthetic_impl(cfg, manifest, plan, resume, clock, chaos, None, &mut on_event)
}

/// Shared body of the synthetic session entry points.
#[allow(clippy::too_many_arguments)]
fn run_synthetic_impl(
    cfg: ExperimentConfig,
    manifest: Arc<Manifest>,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    clock: Option<Arc<dyn Clock>>,
    chaos: Vec<ChaosDeath>,
    obs: Obs,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    let compute = ComputeSpec::Synthetic { manifest };
    let shards = session_shards(&cfg, resume.as_ref());
    let result = (|| {
        let mut session = SessionCtx::build(&cfg, &compute, plan, resume)?;
        if let Some(c) = clock {
            session.clock = c;
        }
        session.chaos = chaos;
        session.obs = obs;
        match cfg.transport {
            TransportKind::Mpsc => run_mpsc_sharded(&cfg, shards, &compute, &mut session, on_event),
            TransportKind::Loopback | TransportKind::Tcp => {
                run_wire_sharded(&cfg, shards, &compute, &mut session, on_event)
            }
        }
    })();
    match &result {
        Ok(log) => on_event(&Event::Finished(log.clone())),
        Err(e) => on_event(&Event::Failed(format!("{e:#}"))),
    }
    result
}

/// Transport dispatch for the sharded deployment shapes.
fn run_sharded_impl(
    cfg: ExperimentConfig,
    compute: ComputeSpec,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    obs: Obs,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    let shards = session_shards(&cfg, resume.as_ref());
    if shards <= 1
        && !cfg.transport.is_wire()
        && matches!(compute, ComputeSpec::Real)
        && cfg.session.is_none()
        && resume.is_none()
        && plan.is_empty()
    {
        return run_single_thread(cfg, obs, on_event);
    }
    let result = (|| {
        let mut session = SessionCtx::build(&cfg, &compute, plan, resume)?;
        session.obs = obs;
        match cfg.transport {
            TransportKind::Mpsc => run_mpsc_sharded(&cfg, shards, &compute, &mut session, on_event),
            TransportKind::Loopback | TransportKind::Tcp => {
                run_wire_sharded(&cfg, shards, &compute, &mut session, on_event)
            }
        }
    })();
    match &result {
        Ok(log) => on_event(&Event::Finished(log.clone())),
        Err(e) => on_event(&Event::Failed(format!("{e:#}"))),
    }
    result
}

/// Shards as threads, typed mpsc channels (no serialization).
fn run_mpsc_sharded(
    cfg: &ExperimentConfig,
    shards: usize,
    compute: &ComputeSpec,
    session: &mut SessionCtx,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    let (msg_tx, msg_rx) = mpsc::channel::<ShardMsg>();
    let mut admit = MpscAdmit {
        cfg: cfg.clone(),
        compute: compute.clone(),
        msg_tx: Some(msg_tx),
        handles: Vec::new(),
        next_conn: 0,
        chaos: std::mem::take(&mut session.chaos),
        obs: session.obs.clone(),
    };
    let mut txs: Vec<ShardTx> = Vec::with_capacity(shards);
    let mut active: Vec<u64> = Vec::with_capacity(shards);
    for shard in 0..shards {
        let (conn, tx) = admit.admit(shard, shards)?;
        active.push(conn);
        txs.push(tx);
    }
    // Static membership keeps no admission sender alive, so the fan-in
    // channel disconnects (and the run fails fast) if every shard dies
    // silently; elastic runs must keep it for later admissions, and
    // supervised runs must keep it for respawns (their exit guards and
    // readers make silent death observable without the disconnect).
    if session.plan.is_empty() && !cfg.policy.supervised() {
        admit.seal();
    }

    let result = coordinate(
        cfg, shards, &mut txs, &mut active, &mut admit, &msg_rx, session, on_event,
    );
    // Shut every shard down (dead shards just return a send error).
    for tx in &mut txs {
        let _ = tx.send(ShardCmd::Stop);
    }
    for h in admit.handles.drain(..) {
        let _ = h.join();
    }
    result
}

/// A Real-compute worker re-opens the artifacts path from the INIT
/// handshake config; reject paths the UTF-8 config encoding would
/// silently mangle instead of failing remotely with a phantom path.
fn check_wire_cfg(cfg: &ExperimentConfig, compute: &ComputeSpec) -> Result<()> {
    if matches!(compute, ComputeSpec::Real) && cfg.artifacts_root.to_str().is_none() {
        return Err(anyhow!(
            "artifacts path {:?} is not valid UTF-8 and cannot cross the config handshake",
            cfg.artifacts_root
        ));
    }
    Ok(())
}

/// Shards as threads speaking the serialized wire protocol (loopback
/// pipes or real localhost TCP sockets).
fn run_wire_sharded(
    cfg: &ExperimentConfig,
    shards: usize,
    compute: &ComputeSpec,
    session: &mut SessionCtx,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    check_wire_cfg(cfg, compute)?;
    // Tree fan-in composes with static, unsupervised membership only: a
    // resize or respawn would re-index the subtree's leaf shards, which
    // the leaf install path (correctly) rejects, and chaos injection
    // targets flat leaf workers. Reject the combination up front rather
    // than failing mid-run with a confusing subtree error.
    if cfg.tree_children > 0
        && (cfg.policy.supervised() || !session.plan.is_empty() || !session.chaos.is_empty())
    {
        return Err(anyhow!(
            "tree aggregation (tree_children > 0) requires static, unsupervised membership: \
             run without an elastic plan, round supervision, or chaos injection"
        ));
    }
    let (msg_tx, msg_rx) = mpsc::channel::<ShardMsg>();
    let mode = match cfg.transport {
        TransportKind::Loopback => WireMode::Loopback,
        TransportKind::Tcp => WireMode::Tcp {
            listener: TcpListener::bind("127.0.0.1:0")
                .map_err(|e| anyhow!("binding shard listener: {e}"))?,
        },
        TransportKind::Mpsc => unreachable!("mpsc is not a wire transport"),
    };
    let mut admit = WireAdmit::new(cfg, compute, msg_tx, Some(mode), session.clock.clone());
    admit.chaos = std::mem::take(&mut session.chaos);
    admit.obs = session.obs.clone();
    let mut txs: Vec<ShardTx> = Vec::with_capacity(shards);
    let mut active: Vec<u64> = Vec::with_capacity(shards);
    for shard in 0..shards {
        let (conn, tx) = admit.admit(shard, shards)?;
        active.push(conn);
        txs.push(tx);
    }
    // Static membership keeps no admission sender alive (see
    // run_mpsc_sharded); elastic runs need it for later admissions and
    // supervised runs for respawns.
    if session.plan.is_empty() && !cfg.policy.supervised() {
        admit.seal();
    }

    let result = coordinate(
        cfg, shards, &mut txs, &mut active, &mut admit, &msg_rx, session, on_event,
    );
    teardown_wire(result, txs, &mut admit)
}

/// Shared wire-coordinator teardown: Stop fan-out, close the write
/// halves so shards (and with them the readers) wind down even on the
/// error path, join everything, and attach the measured frame-layer
/// traffic to a successful log.
fn teardown_wire(
    result: Result<RunLog>,
    mut txs: Vec<ShardTx>,
    admit: &mut WireAdmit<'_>,
) -> Result<RunLog> {
    for tx in &mut txs {
        let _ = tx.send(ShardCmd::Stop);
    }
    drop(txs);
    admit.join_all();
    let stats = admit.wire_stats();
    result.map(|mut log| {
        log.wire = Some(stats);
        log
    })
}

/// Accept one shard connection with a deadline, polling `liveness`
/// while waiting so a dead worker fails the join fast instead of
/// hanging the accept loop. The deadline reads the supervision
/// [`Clock`] (so scripted clocks control join expiry like every other
/// lease); the 10 ms sleep is a wall wakeup only, never a timing
/// source — same split as the coordinator's poll loops.
fn accept_one(
    listener: &TcpListener,
    timeout: Duration,
    clock: &dyn Clock,
    mut liveness: impl FnMut() -> Result<()>,
) -> Result<std::net::TcpStream> {
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow!("listener nonblocking: {e}"))?;
    let deadline = clock.now() + timeout;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream
                    .set_nonblocking(false)
                    .map_err(|e| anyhow!("stream blocking mode: {e}"))?;
                return Ok(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                liveness()?;
                clock.idle_tick();
                if clock.now() > deadline {
                    return Err(anyhow!(
                        "timed out after {timeout:?} waiting for a shard worker to join"
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("accept failed: {e}")),
        }
    }
}

/// One wire connection's receive pump: decode frames into [`ShardMsg`]s
/// for the shared fan-in channel. Any transport error, protocol
/// violation or close is surfaced as a `ConnDown` message carrying this
/// connection's generation; the control loop fails fast when the
/// connection is the shard's active one and ignores it when the shard
/// was deliberately replaced. (A close *after* the control loop
/// finished parks a message nobody reads — harmless.)
fn reader_loop(
    conn: u64,
    shard: usize,
    mut source: FrameSource,
    shared: Arc<WireShared>,
    tx: mpsc::Sender<ShardMsg>,
) {
    let mut manifest: Option<Arc<Manifest>> = None;
    let mut buf = Vec::new();
    loop {
        match source.recv(&mut buf) {
            Ok(true) => {}
            Ok(false) => {
                let _ = tx.send(ShardMsg::ConnDown {
                    conn,
                    shard,
                    msg: "connection closed".into(),
                });
                return;
            }
            Err(e) => {
                let _ = tx.send(ShardMsg::ConnDown {
                    conn,
                    shard,
                    msg: format!("transport receive failed: {e:#}"),
                });
                return;
            }
        }
        match decode_shard_msg(&buf, shard, &mut manifest, &shared.pool) {
            Ok(msg) => {
                if tx.send(msg).is_err() {
                    return; // coordinator gone; nothing left to tell
                }
            }
            Err(e) => {
                let _ = tx.send(ShardMsg::ConnDown {
                    conn,
                    shard,
                    msg: format!("wire decode failed: {e:#}"),
                });
                return;
            }
        }
    }
}

/// Decode one shard→coordinator frame, learning the model contract from
/// the READY handshake and recycling lanes through the shared pool.
fn decode_shard_msg(
    buf: &[u8],
    conn_shard: usize,
    manifest: &mut Option<Arc<Manifest>>,
    pool: &Mutex<Vec<RoundLane>>,
) -> Result<ShardMsg> {
    match wire::msg_tag(buf)? {
        MsgTag::Ready => {
            let (shard, init) = wire::decode_ready(buf)?;
            if shard != conn_shard {
                return Err(anyhow!(
                    "READY claims shard {shard} on connection {conn_shard}"
                ));
            }
            *manifest = Some(init.manifest.clone());
            Ok(ShardMsg::Ready { shard, init })
        }
        MsgTag::RoundDone => {
            let m = manifest
                .as_ref()
                .ok_or_else(|| anyhow!("ROUND_DONE before READY handshake"))?;
            let mut free = pool.lock().map_err(|_| anyhow!("lane pool poisoned"))?;
            let (shard, lanes) = wire::decode_round_done_into(buf, m, &mut free)?;
            drop(free);
            if shard != conn_shard {
                return Err(anyhow!(
                    "ROUND_DONE claims shard {shard} on connection {conn_shard}"
                ));
            }
            Ok(ShardMsg::RoundDone { shard, lanes })
        }
        MsgTag::Eval => {
            let (report, scale_stats) = wire::decode_eval(buf)?;
            Ok(ShardMsg::Eval {
                report,
                scale_stats,
            })
        }
        MsgTag::State => {
            let (shard, clients) = wire::decode_state_msg(buf)?;
            if shard != conn_shard {
                return Err(anyhow!(
                    "STATE claims shard {shard} on connection {conn_shard}"
                ));
            }
            Ok(ShardMsg::State { shard, clients })
        }
        MsgTag::Failed => {
            let (shard, msg) = wire::decode_failed(buf)?;
            Ok(ShardMsg::Failed { shard, msg })
        }
        MsgTag::Heartbeat => {
            let (shard, nonce) = wire::decode_heartbeat_msg(buf)?;
            if shard != conn_shard {
                return Err(anyhow!(
                    "HEARTBEAT claims shard {shard} on connection {conn_shard}"
                ));
            }
            Ok(ShardMsg::Heartbeat { shard, nonce })
        }
    }
}

/// Receive the next relevant shard message, translating an active
/// connection's `ConnDown` into a shard failure and discarding stale
/// reports from deliberately-replaced connections.
fn next_msg(msg_rx: &mpsc::Receiver<ShardMsg>, active: &[u64]) -> Result<ShardMsg> {
    loop {
        match msg_rx.recv() {
            Ok(ShardMsg::ConnDown { conn, shard, msg }) => {
                if active.get(shard).map_or(true, |&a| a == conn) {
                    return Ok(ShardMsg::Failed { shard, msg });
                }
                // A replaced shard's old reader winding down — ignore.
            }
            Ok(m) => return Ok(m),
            Err(_) => return Err(anyhow!("all shard channels closed")),
        }
    }
}

/// Fan a collect-only STATE command to every **live** shard and gather
/// the returned client states (any arrival order), sorted by client id
/// — the shared read half of checkpoints and resizes. Degraded slots
/// hold a dead sender whose send can only fail, so they are masked out
/// by `live` rather than treated as a collect failure (the degrade
/// already folded their clients onto survivors). Late heartbeat echoes
/// are liveness-only and may still be in flight at a round boundary —
/// they are skipped, not errors. `what` names the operation in error
/// messages.
fn collect_all_states(
    txs: &mut [ShardTx],
    msg_rx: &mpsc::Receiver<ShardMsg>,
    active: &[u64],
    live: &[bool],
    what: &str,
) -> Result<Vec<ClientState>> {
    let mut expected = 0usize;
    for (s, tx) in txs.iter_mut().enumerate() {
        if !live.get(s).copied().unwrap_or(true) {
            continue;
        }
        expected += 1;
        tx.send(ShardCmd::State(StateCmd {
            collect: true,
            install: None,
        }))
        .map_err(|_| {
            shard_failure(
                msg_rx,
                active,
                &format!("shard {s} disconnected during {what}"),
            )
        })?;
    }
    let mut clients: Vec<ClientState> = Vec::new();
    let mut got = 0usize;
    while got < expected {
        match next_msg(msg_rx, active) {
            Ok(ShardMsg::State { clients: c, .. }) => {
                got += 1;
                clients.extend(c);
            }
            Ok(ShardMsg::Heartbeat { .. }) => {}
            Ok(ShardMsg::Failed { shard, msg }) => return Err(anyhow!("shard {shard}: {msg}")),
            Ok(_) => return Err(anyhow!("unexpected shard message during {what}")),
            Err(e) => return Err(e),
        }
    }
    clients.sort_by_key(|c| c.id);
    Ok(clients)
}

/// Turn a dead-shard condition into its parked `Failed` message when one
/// is already queued, otherwise the fallback description.
fn shard_failure(
    msg_rx: &mpsc::Receiver<ShardMsg>,
    active: &[u64],
    fallback: &str,
) -> anyhow::Error {
    while let Ok(m) = msg_rx.try_recv() {
        match m {
            ShardMsg::Failed { shard, msg } => return anyhow!("shard {shard}: {msg}"),
            ShardMsg::ConnDown { conn, shard, msg } => {
                if active.get(shard).map_or(true, |&a| a == conn) {
                    return anyhow!("shard {shard}: {msg}");
                }
            }
            _ => {}
        }
    }
    anyhow!("{fallback}")
}

// ---------------------------------------------------------------------------
// Round supervision (heartbeats, deadlines, recovery)
// ---------------------------------------------------------------------------

/// What a supervised wait produced: a regular message, or a shard
/// declared dead (by its connection tearing down, its own FAILED
/// report, a round deadline, or an expired liveness lease).
enum Waited {
    Msg(ShardMsg),
    Dead {
        shard: usize,
        reason: String,
        /// Whether the death was observed as the connection itself
        /// going down (its channel is already fully drained) — when
        /// false, recovery must still quarantine the old connection.
        conn_down: bool,
    },
}

/// The coordinator-side state needed to rewind the world to the last
/// completed-round boundary: `rounds_done` rounds are final, `params`
/// is the server model at that boundary and `clients` the
/// round-boundary client states (empty on the synthetic plane, whose
/// client outputs are pure functions of round seed and id).
struct RecoveryCache {
    rounds_done: usize,
    params: ParamSet,
    clients: Vec<ClientState>,
}

/// Mutable supervision state threaded through the control loop.
struct Supervision {
    /// Per-shard liveness: degraded shards are `false` and their slots
    /// are never reused (messages from them are discarded).
    live: Vec<bool>,
    /// Client → shard assignment. Starts as round-robin; degradation
    /// folds a dead shard's clients into the survivors.
    assign: Vec<usize>,
    /// Which shard evaluates the central model (lowest live index).
    eval_shard: usize,
    /// Last time each shard was heard from (lease bookkeeping).
    last_seen: Vec<Duration>,
    /// When the next heartbeat probe fan-out is due.
    next_hb: Duration,
    /// Monotonic heartbeat nonce (probes + recovery barriers).
    hb_nonce: u64,
    /// Rewind target for recovery.
    cache: RecoveryCache,
}

/// A sender whose every send fails: installed in a dead shard's slot so
/// `txs` keeps its indexing (degraded slots are never truncated) and —
/// for a wire shard — the old sink drops, hanging up on the worker.
fn dead_tx() -> ShardTx {
    let (tx, _rx) = mpsc::channel::<ShardCmd>();
    ShardTx::Mpsc(tx)
}

/// One supervised receive: polls the fan-in channel at [`SUP_POLL`]
/// granularity so it can fan out heartbeat probes, advance a scripted
/// clock, and enforce the phase `deadline` (over shards with
/// `busy[s]` set — the ones allowed to be silently computing) and the
/// heartbeat lease (over idle live shards). Messages from non-live
/// (degraded) shards and stale connections are discarded.
#[allow(clippy::too_many_arguments)]
fn sup_wait(
    sup: &mut Supervision,
    txs: &mut [ShardTx],
    active: &[u64],
    msg_rx: &mpsc::Receiver<ShardMsg>,
    clock: &dyn Clock,
    policy: &RoundPolicy,
    busy: &[bool],
    deadline: Option<Duration>,
) -> Result<Waited> {
    loop {
        let now = clock.now();
        if !policy.heartbeat.is_zero() && now >= sup.next_hb {
            sup.hb_nonce += 1;
            let nonce = sup.hb_nonce;
            for (s, tx) in txs.iter_mut().enumerate() {
                if sup.live[s] {
                    // A failed probe send is not itself a death verdict;
                    // the connection teardown will surface one.
                    let _ = tx.send(ShardCmd::Heartbeat { nonce });
                }
            }
            sup.next_hb = now + policy.heartbeat;
        }
        match msg_rx.recv_timeout(SUP_POLL) {
            Ok(ShardMsg::ConnDown { conn, shard, msg }) => {
                let stale = active.get(shard).is_some_and(|&a| a != conn);
                if stale || !sup.live.get(shard).copied().unwrap_or(false) {
                    continue;
                }
                return Ok(Waited::Dead {
                    shard,
                    reason: msg,
                    conn_down: true,
                });
            }
            Ok(ShardMsg::Failed { shard, msg }) => {
                if !sup.live.get(shard).copied().unwrap_or(false) {
                    continue;
                }
                return Ok(Waited::Dead {
                    shard,
                    reason: msg,
                    conn_down: false,
                });
            }
            Ok(ShardMsg::Heartbeat { shard, .. }) => {
                if let Some(seen) = sup.last_seen.get_mut(shard) {
                    *seen = clock.now();
                }
                continue;
            }
            Ok(m) => {
                let from = match &m {
                    ShardMsg::Ready { shard, .. }
                    | ShardMsg::RoundDone { shard, .. }
                    | ShardMsg::State { shard, .. } => Some(*shard),
                    ShardMsg::Eval { .. } => Some(sup.eval_shard),
                    _ => None,
                };
                if let Some(s) = from {
                    if !sup.live.get(s).copied().unwrap_or(false) {
                        continue; // a degraded straggler's late message
                    }
                    if let Some(seen) = sup.last_seen.get_mut(s) {
                        *seen = clock.now();
                    }
                }
                return Ok(Waited::Msg(m));
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                clock.idle_tick();
                let now = clock.now();
                if let Some(d) = deadline {
                    if now >= d {
                        if let Some(s) = (0..sup.live.len())
                            .find(|&s| sup.live[s] && busy.get(s).copied().unwrap_or(false))
                        {
                            return Ok(Waited::Dead {
                                shard: s,
                                reason: format!(
                                    "exceeded the round deadline ({:?})",
                                    policy.round_deadline
                                ),
                                conn_down: false,
                            });
                        }
                    }
                }
                if !policy.heartbeat.is_zero() {
                    let lease = policy.heartbeat * LEASE_INTERVALS;
                    if let Some(s) = (0..sup.live.len()).find(|&s| {
                        sup.live[s]
                            && !busy.get(s).copied().unwrap_or(false)
                            && now.saturating_sub(sup.last_seen[s]) > lease
                    }) {
                        return Ok(Waited::Dead {
                            shard: s,
                            reason: format!("liveness lease expired ({lease:?} without an echo)"),
                            conn_down: false,
                        });
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("all shard channels closed"))
            }
        }
    }
}

/// Wait (clock-driven, up to `timeout`) for the dead shard's connection
/// teardown report, discarding its dying gasps and any stale round
/// traffic. Returns whether the teardown was observed — `false` means
/// the old incarnation may still be wedged on an open connection, so
/// its index must not be reused. A *second* live shard failing during
/// the drain aborts: recovery is single-fault per incident.
fn drain_conn_down(
    dead: usize,
    msg_rx: &mpsc::Receiver<ShardMsg>,
    active: &[u64],
    live: &[bool],
    clock: &dyn Clock,
    timeout: Duration,
) -> Result<bool> {
    let deadline = clock.now() + timeout;
    loop {
        match msg_rx.recv_timeout(SUP_POLL) {
            Ok(ShardMsg::ConnDown { conn, shard, .. }) => {
                if shard == dead && active.get(dead).is_some_and(|&a| a == conn) {
                    return Ok(true);
                }
                let stale = active.get(shard).is_some_and(|&a| a != conn);
                if !stale && shard != dead && live.get(shard).copied().unwrap_or(false) {
                    return Err(anyhow!(
                        "shard {shard} also failed while recovering shard {dead} \
                         (recovery handles one fault at a time)"
                    ));
                }
            }
            Ok(ShardMsg::Failed { shard, msg }) => {
                if shard != dead && live.get(shard).copied().unwrap_or(false) {
                    return Err(anyhow!(
                        "shard {shard} also failed while recovering shard {dead}: {msg}"
                    ));
                }
            }
            Ok(_) => {} // stale traffic; a rewind will replay the round
            Err(mpsc::RecvTimeoutError::Timeout) => {
                clock.idle_tick();
                if clock.now() >= deadline {
                    return Ok(false);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("all shard channels closed"));
            }
        }
    }
}

/// Post-recovery synchronization barrier: probe every live shard with a
/// fresh heartbeat nonce and drain the fan-in channel until each has
/// echoed it. Per-connection FIFO ordering then guarantees no stale
/// pre-recovery message is still in flight anywhere — everything
/// drained on the way is replay-obsolete traffic.
fn barrier_flush(
    sup: &mut Supervision,
    txs: &mut [ShardTx],
    active: &[u64],
    msg_rx: &mpsc::Receiver<ShardMsg>,
    clock: &dyn Clock,
    policy: &RoundPolicy,
) -> Result<()> {
    sup.hb_nonce += 1;
    let nonce = sup.hb_nonce;
    for (s, tx) in txs.iter_mut().enumerate() {
        if sup.live[s] {
            tx.send(ShardCmd::Heartbeat { nonce }).map_err(|_| {
                anyhow!("shard {s} disconnected during the recovery barrier")
            })?;
        }
    }
    let mut pending: Vec<bool> = sup.live.clone();
    let deadline = clock.now() + policy.join_timeout;
    while pending.iter().any(|&p| p) {
        match msg_rx.recv_timeout(SUP_POLL) {
            Ok(ShardMsg::Heartbeat { shard, nonce: n }) => {
                if let Some(seen) = sup.last_seen.get_mut(shard) {
                    *seen = clock.now();
                }
                if n == nonce {
                    if let Some(p) = pending.get_mut(shard) {
                        *p = false;
                    }
                }
            }
            Ok(ShardMsg::ConnDown { conn, shard, msg }) => {
                let stale = active.get(shard).is_some_and(|&a| a != conn);
                if !stale && sup.live.get(shard).copied().unwrap_or(false) {
                    return Err(anyhow!(
                        "shard {shard} died during the recovery barrier: {msg}"
                    ));
                }
            }
            Ok(ShardMsg::Failed { shard, msg }) => {
                if sup.live.get(shard).copied().unwrap_or(false) {
                    return Err(anyhow!(
                        "shard {shard} failed during the recovery barrier: {msg}"
                    ));
                }
            }
            Ok(_) => {} // stale round traffic being flushed
            Err(mpsc::RecvTimeoutError::Timeout) => {
                clock.idle_tick();
                if clock.now() >= deadline {
                    return Err(anyhow!(
                        "recovery barrier timed out after {:?}",
                        policy.join_timeout
                    ));
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("all shard channels closed"));
            }
        }
    }
    Ok(())
}

/// Wait for a freshly respawned shard's READY handshake. `Ok(true)` on
/// READY, `Ok(false)` when this attempt's worker died or the join
/// timeout lapsed (the caller retries or degrades), `Err` on a second
/// live-shard fault.
fn wait_respawn_ready(
    dead: usize,
    msg_rx: &mpsc::Receiver<ShardMsg>,
    active: &[u64],
    live: &[bool],
    clock: &dyn Clock,
    timeout: Duration,
) -> Result<bool> {
    let deadline = clock.now() + timeout;
    loop {
        match msg_rx.recv_timeout(SUP_POLL) {
            Ok(ShardMsg::Ready { shard, .. }) if shard == dead => return Ok(true),
            Ok(ShardMsg::ConnDown { conn, shard, .. }) => {
                if shard == dead && active.get(dead).is_some_and(|&a| a == conn) {
                    return Ok(false);
                }
                let stale = active.get(shard).is_some_and(|&a| a != conn);
                if !stale && shard != dead && live.get(shard).copied().unwrap_or(false) {
                    return Err(anyhow!(
                        "shard {shard} also failed while shard {dead} was respawning"
                    ));
                }
            }
            Ok(ShardMsg::Failed { shard, msg }) => {
                if shard != dead && live.get(shard).copied().unwrap_or(false) {
                    return Err(anyhow!(
                        "shard {shard} also failed while shard {dead} was respawning: {msg}"
                    ));
                }
                // The respawn candidate's own FAILED: wait for its
                // ConnDown so the attempt winds down cleanly.
            }
            Ok(_) => {} // stale traffic; the barrier flush follows
            Err(mpsc::RecvTimeoutError::Timeout) => {
                clock.idle_tick();
                if clock.now() >= deadline {
                    return Ok(false);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("all shard channels closed"));
            }
        }
    }
}

/// The recovery state machine, run when a live shard is declared dead
/// mid-round:
///
/// 1. **Quarantine** — hang up on the old incarnation and consume its
///    connection-teardown report, so nothing it ever sent can be
///    mistaken for its replacement's traffic.
/// 2. **Respawn** (`on-shard-loss=respawn`) — re-admit a worker under
///    the departed index, up to `retry_budget` attempts with
///    exponential, seed-jittered backoff between them.
/// 3. **Degrade** (`on-shard-loss=degrade`, or a respawn budget
///    exhausted) — mark the shard dead for good and fold its clients
///    deterministically into the survivors (quorum mode); the lowest
///    live shard becomes the evaluator.
/// 4. **Barrier-flush** every surviving channel (heartbeat nonce echo),
///    then **rewind the world**: restore the server model from the
///    recovery cache and install the cached round-boundary state on
///    every live shard. The caller then replays the round from fan-out;
///    determinism makes the replay byte-identical to an undisturbed
///    round.
#[allow(clippy::too_many_arguments)]
fn recover(
    cfg: &ExperimentConfig,
    t: usize,
    shards: usize,
    dead: usize,
    reason: String,
    conn_down: bool,
    sup: &mut Supervision,
    txs: &mut [ShardTx],
    active: &mut [u64],
    admit: &mut dyn Admit,
    msg_rx: &mpsc::Receiver<ShardMsg>,
    clock: &dyn Clock,
    server: &mut Server,
    log: &mut RunLog,
) -> Result<()> {
    let policy = &cfg.policy;
    log.events.push(ShardEvent {
        round: t,
        shard: dead,
        kind: ShardEventKind::Death {
            reason: reason.clone(),
        },
    });
    if policy.on_loss == OnShardLoss::Abort {
        return Err(anyhow!("shard {dead}: {reason}"));
    }
    // 1 · quarantine the old incarnation.
    txs[dead] = dead_tx();
    let gone = conn_down
        || drain_conn_down(dead, msg_rx, active, &sup.live, clock, policy.join_timeout)?;
    active[dead] = 0;
    // 2 · respawn with backoff. A never-observed teardown (a wedged
    //     straggler) forbids reusing the index — fall through to
    //     degradation instead.
    let mut respawned = false;
    if policy.on_loss == OnShardLoss::Respawn && gone {
        let seed = cfg.seed ^ (t as u64).rotate_left(17) ^ (dead as u64).rotate_left(41);
        let mut backoff = Backoff::new(policy.backoff, policy.backoff.saturating_mul(32), seed);
        for attempt in 1..=policy.retry_budget.max(1) {
            clock.sleep(backoff.next_delay());
            let Ok((conn, tx)) = admit.admit(dead, shards) else {
                continue;
            };
            txs[dead] = tx;
            active[dead] = conn;
            if wait_respawn_ready(dead, msg_rx, active, &sup.live, clock, policy.join_timeout)? {
                log.events.push(ShardEvent {
                    round: t,
                    shard: dead,
                    kind: ShardEventKind::Respawned { attempt },
                });
                respawned = true;
                break;
            }
            // This attempt's worker died or never came up: quarantine
            // it too and try again.
            txs[dead] = dead_tx();
            let _ = drain_conn_down(dead, msg_rx, active, &sup.live, clock, policy.join_timeout)?;
            active[dead] = 0;
        }
    }
    // 3 · graceful degradation when the budget is spent (or scripted).
    if !respawned {
        sup.live[dead] = false;
        let survivors: Vec<usize> = (0..shards).filter(|&s| sup.live[s]).collect();
        if survivors.is_empty() {
            return Err(anyhow!(
                "shard {dead}: {reason} — and no live shards remain to absorb its clients"
            ));
        }
        let mut moved = Vec::new();
        for (c, a) in sup.assign.iter_mut().enumerate() {
            if *a == dead {
                *a = survivors[c % survivors.len()];
                moved.push(c);
            }
        }
        sup.eval_shard = survivors[0];
        log.events.push(ShardEvent {
            round: t,
            shard: dead,
            kind: ShardEventKind::Degraded { clients: moved },
        });
    }
    // 4 · flush, then rewind the world to the round-t boundary.
    barrier_flush(sup, txs, active, msg_rx, clock, policy)?;
    server.params.copy_from(&sup.cache.params);
    for s in 0..txs.len() {
        if !sup.live[s] {
            continue;
        }
        let owned: Vec<ClientState> = sup
            .cache
            .clients
            .iter()
            .filter(|c| sup.assign.get(c.id).copied() == Some(s))
            .cloned()
            .collect();
        txs[s]
            .send(ShardCmd::State(StateCmd {
                collect: false,
                install: Some(StateInstall {
                    shard: s,
                    shards,
                    rounds_done: sup.cache.rounds_done as u64,
                    params: sup.cache.params.clone(),
                    clients: owned,
                }),
            }))
            .map_err(|_| anyhow!("shard {s} disconnected during the rewind install"))?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The control loop
// ---------------------------------------------------------------------------

/// The coordinator's control loop: round fan-out, ordered fan-in
/// reduction, FedAvg, broadcast, metrics — plus the session plane
/// (resume install, checkpoint collection, elastic membership).
/// Transport-oblivious — it talks [`ShardTx`]/[`ShardMsg`] and never
/// sees frames.
#[allow(clippy::too_many_arguments)]
fn coordinate(
    cfg: &ExperimentConfig,
    shards: usize,
    txs: &mut Vec<ShardTx>,
    active: &mut Vec<u64>,
    admit: &mut dyn Admit,
    msg_rx: &mpsc::Receiver<ShardMsg>,
    session: &mut SessionCtx,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    // The *current* shard count: elastic resizes re-bind it mid-run.
    // `txs` always holds exactly `shards` senders; `active` is indexed
    // by shard and never shrinks (a departed shard's slot is zeroed so
    // its reader's late ConnDown is recognized as stale, not fatal).
    let mut shards = shards;
    // Startup barrier: every shard builds its runtime + clients.
    let mut init: Option<ParamSet> = None;
    let mut ready = 0usize;
    while ready < shards {
        match next_msg(msg_rx, active) {
            Ok(ShardMsg::Ready { shard, init: i }) => {
                debug_assert!(shard < shards, "ready from unknown shard {shard}");
                ready += 1;
                if init.is_none() {
                    init = Some(i);
                }
            }
            Ok(ShardMsg::Failed { shard, msg }) => return Err(anyhow!("shard {shard}: {msg}")),
            Ok(_) => return Err(anyhow!("unexpected shard message during startup")),
            Err(_) => {
                return Err(shard_failure(
                    msg_rx,
                    active,
                    "shards exited during startup",
                ))
            }
        }
    }
    let init =
        init.ok_or_else(|| anyhow!("startup barrier passed without an init model (no READY)"))?;

    // Passive telemetry handle: every touch below is gated on the
    // option, so telemetry-off runs pay one branch per site and
    // allocate nothing.
    let obs = session.obs.clone();
    if let Some(t) = &obs {
        t.metrics.set_model_params(init.numel());
    }

    let mut server = Server::new(init, cfg.downstream_codec());
    let mut log = RunLog::new(cfg.name.clone());
    let mut start_round = 0usize;
    let mut resume_clients: Vec<ClientState> = Vec::new();

    // Client → shard ownership map: round-robin at startup, and the
    // SINGLE source of truth from here on (it moves into
    // `Supervision::assign` below). Every install fan-out and round
    // fan-out reads this map; only membership events — resume install,
    // elastic resize, quorum degradation — recompute it. Re-deriving
    // ownership arithmetically at a use site can silently disagree with
    // what the shards were actually told after a degrade or replace.
    let n = cfg.clients;
    let assign: Vec<usize> = (0..n).map(|c| scheduler::shard_of(c, shards)).collect();

    // ---- session resume: rebuild the server from the snapshot and
    //      rehydrate every shard over the STATE pair ----
    if let Some(state) = session.resume.take() {
        // The experiment itself must be re-run verbatim; the session
        // block (checkpoint dir/cadence/fault injection) and the round
        // supervision policy (heartbeats, deadlines, loss handling) are
        // operational and may legitimately differ on resume, so they
        // are normalized out of the comparison.
        let mut ours_cfg = cfg.clone();
        ours_cfg.session = None;
        ours_cfg.policy = RoundPolicy::default();
        let mut theirs_cfg = state.cfg.clone();
        theirs_cfg.session = None;
        theirs_cfg.policy = RoundPolicy::default();
        let mut ours = Vec::new();
        let mut theirs = Vec::new();
        wire::encode_config(&mut ours, &ours_cfg);
        wire::encode_config(&mut theirs, &theirs_cfg);
        if ours != theirs {
            return Err(anyhow!(
                "resume config does not match the snapshot's experiment config \
                 (resume re-runs the snapshot's experiment verbatim)"
            ));
        }
        let manifest = server.params.manifest.clone();
        if state.manifest_tsv != manifest.to_tsv() {
            return Err(anyhow!(
                "resume model contract mismatch: the snapshot's manifest differs \
                 from the shards' READY manifest"
            ));
        }
        if state.next_round > cfg.rounds {
            return Err(anyhow!(
                "snapshot says {} rounds are done but the config runs only {}",
                state.next_round,
                cfg.rounds
            ));
        }
        if state.shards.min(cfg.clients).max(1) != shards {
            return Err(anyhow!(
                "snapshot was taken with {} shards but {} workers joined \
                 (resume rebuilds the checkpointed post-resize membership)",
                state.shards,
                shards
            ));
        }
        let params = state.params_for(&manifest)?;
        server = Server::new(params, cfg.downstream_codec());
        log.rounds = state.rounds.clone();
        start_round = state.next_round;
        for (s, tx) in txs.iter_mut().enumerate() {
            let owned: Vec<ClientState> = state
                .clients
                .iter()
                .filter(|c| assign.get(c.id).copied() == Some(s))
                .cloned()
                .collect();
            tx.send(ShardCmd::State(StateCmd {
                collect: false,
                install: Some(StateInstall {
                    shard: s,
                    shards,
                    rounds_done: state.next_round as u64,
                    params: server.params.clone(),
                    clients: owned,
                }),
            }))
            .map_err(|_| {
                shard_failure(msg_rx, active, &format!("shard {s} disconnected during resume"))
            })?;
        }
        resume_clients = state.clients;
    }

    // Validate the membership plan up front: a silently-ignored event
    // would not just skip the replacement, it would also keep the
    // admission sender alive forever (see the seal below) and disable
    // fail-fast on silent worker death. The walk simulates the shard
    // count through the timeline so replacements are checked against
    // the membership they will actually see.
    let timeline = session.plan.timeline();
    {
        let mut cur = shards;
        for &(round, ev) in &timeline {
            if round < start_round || round >= cfg.rounds {
                return Err(anyhow!(
                    "elastic plan schedules an event at round {round}, outside the \
                     remaining rounds {start_round}..{}",
                    cfg.rounds
                ));
            }
            match ev {
                ElasticEvent::Replace(s) => {
                    if s >= cur {
                        return Err(anyhow!(
                            "elastic plan replaces shard {s} but only {cur} shards \
                             exist at round {round}"
                        ));
                    }
                }
                ElasticEvent::Resize(m) => {
                    if m == 0 || m > cfg.clients {
                        return Err(anyhow!(
                            "elastic plan resizes to {m} shards at round {round}; \
                             valid counts are 1..={} (the client count)",
                            cfg.clients
                        ));
                    }
                    cur = m;
                }
            }
        }
    }
    let last_event_round = session.plan.last_event_round();

    let update_idx = server.params.manifest.update_indices();
    let take = ((cfg.participation * n as f64).round() as usize).clamp(1, n);
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut broadcast = Delta::zeros(server.params.manifest.clone());
    // Recycled Arc for the broadcast fan-out: by the time the next round
    // aggregates, every shard has applied and dropped its clone, so the
    // buffer is uniquely owned again and no model-sized allocation
    // happens in steady state (a slow shard only costs a fallback copy).
    let mut bc_slot: Option<Arc<Delta>> = None;
    // Same recycling for the once-encoded downstream APPLY stream.
    let mut stream_slot: Option<Arc<Vec<u8>>> = None;

    // ---- round supervision state (heartbeats, deadlines, recovery) ----
    let policy = cfg.policy.clone();
    let supervised = policy.supervised();
    let clock = session.clock.clone();
    let mut sup = Supervision {
        live: vec![true; shards],
        assign,
        eval_shard: 0,
        last_seen: vec![clock.now(); shards],
        next_hb: clock.now(),
        hb_nonce: 0,
        cache: RecoveryCache {
            rounds_done: start_round,
            params: server.params.clone(),
            clients: resume_clients,
        },
    };
    // Real-compute supervised runs rewind client state from the cache;
    // prime it with an initial collect (the collect doubles as an
    // acknowledgement barrier for any resume install above). The
    // synthetic plane's clients are stateless — nothing to cache.
    if supervised && !session.synthetic && sup.cache.clients.is_empty() && start_round < cfg.rounds
    {
        sup.cache.clients = collect_all_states(
            txs,
            msg_rx,
            active,
            &sup.live,
            "the recovery-cache prime",
        )?;
    }

    for t in start_round..cfg.rounds {
        // Round-scoped telemetry: stamp the round cell (spans recorded
        // anywhere below inherit it) and open the wall-clock bracket
        // the `round` span closes at the bottom of the loop.
        let round_t0 = obs.as_ref().map(|ob| {
            ob.set_round(t as i64);
            ob.now_ns()
        });
        // ---- elastic membership: scripted events at this round
        //      boundary (replacements first, then resizes) ----
        for &(round, ev) in &timeline {
            if round != t {
                continue;
            }
            match ev {
                // Replacement: collect state → stop → admit → READY →
                // install under the unchanged assignment.
                ElasticEvent::Replace(s) => {
                    // 1 · collect the departing shard's client state.
                    txs[s]
                        .send(ShardCmd::State(StateCmd {
                            collect: true,
                            install: None,
                        }))
                        .map_err(|_| {
                            shard_failure(
                                msg_rx,
                                active,
                                &format!("shard {s} disconnected before handoff"),
                            )
                        })?;
                    let migrated = loop {
                        match next_msg(msg_rx, active) {
                            Ok(ShardMsg::State { shard, clients }) if shard == s => break clients,
                            Ok(ShardMsg::Heartbeat { .. }) => {}
                            Ok(ShardMsg::Failed { shard, msg }) => {
                                return Err(anyhow!("shard {shard}: {msg}"))
                            }
                            Ok(_) => {
                                return Err(anyhow!(
                                    "unexpected shard message while collecting shard {s}'s state"
                                ))
                            }
                            Err(e) => return Err(e),
                        }
                    };
                    // 2 · stop it and provision the replacement under the
                    //     same index; its old connection becomes stale.
                    let _ = txs[s].send(ShardCmd::Stop);
                    let (conn, tx) = admit.admit(s, shards)?;
                    txs[s] = tx;
                    active[s] = conn;
                    // 3 · the newcomer introduces itself through the
                    //     ordinary READY handshake (the elastic re-join
                    //     point).
                    loop {
                        match next_msg(msg_rx, active) {
                            Ok(ShardMsg::Ready { shard, .. }) if shard == s => break,
                            Ok(ShardMsg::Heartbeat { .. }) => {}
                            Ok(ShardMsg::Failed { shard, msg }) => {
                                return Err(anyhow!("shard {shard}: {msg}"))
                            }
                            Ok(_) => {
                                return Err(anyhow!(
                                    "unexpected shard message while shard {s} was re-joining"
                                ))
                            }
                            Err(e) => return Err(e),
                        }
                    }
                    // 4 · rehydrate it: absolute replica params + the
                    //     migrated client states + the fast-forwarded
                    //     round counter.
                    txs[s]
                        .send(ShardCmd::State(StateCmd {
                            collect: false,
                            install: Some(StateInstall {
                                shard: s,
                                shards,
                                rounds_done: t as u64,
                                params: server.params.clone(),
                                clients: migrated,
                            }),
                        }))
                        .map_err(|_| {
                            shard_failure(
                                msg_rx,
                                active,
                                &format!("shard {s} disconnected during re-join"),
                            )
                        })?;
                    sup.last_seen[s] = clock.now();
                }
                // Resize N→M: collect *all* state, stop leavers / admit
                // newcomers, then install the recomputed assignment on
                // every member so each client's residuals, moments, RNG
                // and schedule land on the worker that now owns it.
                ElasticEvent::Resize(target) => {
                    if target == shards && sup.live.iter().take(shards).all(|&l| l) {
                        continue; // no-op resize: same count, full quorum
                    }
                    // 1 · collect every live shard's client state. A
                    //     degraded slot holds a dead sender and its
                    //     clients already live on survivors, so the
                    //     live mask is what makes a resize after quorum
                    //     degradation heal instead of erroring (a
                    //     same-size resize re-admits the dead slots).
                    let clients = collect_all_states(
                        txs,
                        msg_rx,
                        active,
                        &sup.live,
                        &format!("the {shards}->{target} resize"),
                    )?;
                    // 2 · shrink: stop the departing shards (a dead
                    //     slot's send fails harmlessly); their readers'
                    //     late ConnDown reports become stale.
                    if target < shards {
                        for s in target..shards {
                            let _ = txs[s].send(ShardCmd::Stop);
                            active[s] = 0;
                        }
                        txs.truncate(target);
                    }
                    // 3 · admit a worker into every fresh slot — both
                    //     the grown tail and any degraded slot being
                    //     healed — under the new count, then barrier on
                    //     their READY handshakes (any order).
                    let mut pending: Vec<bool> = vec![false; target];
                    let mut waiting = 0usize;
                    for s in 0..target {
                        let fresh =
                            s >= txs.len() || !sup.live.get(s).copied().unwrap_or(false);
                        if !fresh {
                            continue;
                        }
                        let (conn, tx) = admit.admit(s, target)?;
                        if s < txs.len() {
                            txs[s] = tx;
                        } else {
                            txs.push(tx);
                        }
                        if s < active.len() {
                            active[s] = conn;
                        } else {
                            active.push(conn);
                        }
                        pending[s] = true;
                        waiting += 1;
                    }
                    while waiting > 0 {
                        match next_msg(msg_rx, active) {
                            Ok(ShardMsg::Ready { shard, .. })
                                if pending.get(shard).copied().unwrap_or(false) =>
                            {
                                pending[shard] = false;
                                waiting -= 1;
                            }
                            Ok(ShardMsg::Heartbeat { .. }) => {}
                            Ok(ShardMsg::Failed { shard, msg }) => {
                                return Err(anyhow!("shard {shard}: {msg}"))
                            }
                            Ok(_) => {
                                return Err(anyhow!(
                                    "unexpected shard message while shards joined for \
                                     the {shards}->{target} resize"
                                ))
                            }
                            Err(e) => return Err(e),
                        }
                    }
                    shards = target;
                    // 4 · recompute the ownership map ONCE and install
                    //     it everywhere: every member (kept, healed or
                    //     new) gets the absolute params, the
                    //     fast-forwarded round counter, and exactly the
                    //     client states it now owns. The same map then
                    //     becomes `sup.assign` below — the install and
                    //     the fan-out can never drift apart.
                    let assign: Vec<usize> =
                        (0..n).map(|c| scheduler::shard_of(c, shards)).collect();
                    for s in 0..shards {
                        let owned: Vec<ClientState> = clients
                            .iter()
                            .filter(|c| assign.get(c.id).copied() == Some(s))
                            .cloned()
                            .collect();
                        txs[s]
                            .send(ShardCmd::State(StateCmd {
                                collect: false,
                                install: Some(StateInstall {
                                    shard: s,
                                    shards,
                                    rounds_done: t as u64,
                                    params: server.params.clone(),
                                    clients: owned,
                                }),
                            }))
                            .map_err(|_| {
                                shard_failure(
                                    msg_rx,
                                    active,
                                    &format!("shard {s} disconnected during resize install"),
                                )
                            })?;
                    }
                    // Re-anchor supervision to the new membership: all
                    // members are live, the assignment is the installed
                    // map, and the rewind cache carries the
                    // just-collected states under the new shard count.
                    sup.live = vec![true; shards];
                    sup.assign = assign;
                    sup.eval_shard = 0;
                    sup.last_seen = vec![clock.now(); shards];
                    if supervised {
                        sup.cache = RecoveryCache {
                            rounds_done: t,
                            params: server.params.clone(),
                            clients,
                        };
                    }
                }
            }
        }
        // Once the last planned membership change is behind us, no
        // further admission can happen — release the retained fan-in
        // sender so silent worker death still disconnects the channel
        // (static-membership runs seal before the control loop starts).
        // Supervised runs never seal: a respawn may admit at any time.
        if last_event_round.map_or(false, |r| r <= t) && !supervised {
            admit.seal();
        }

        scheduler::select_participants(cfg.seed, t, n, take, &mut order);
        let need_states = supervised && !session.synthetic;
        let checkpoint_due =
            session.store.is_some() && session.every > 0 && (t + 1) % session.every == 0;

        // The round attempt loop: a supervised round replays from here
        // after a recovery — the world was rewound to the round-t
        // boundary, so determinism makes the replay byte-identical to
        // an undisturbed round. Unsupervised runs error out of their
        // first attempt exactly as before.
        let (m, collected) = 'attempt: loop {
            let attempt_deadline = if supervised && !policy.round_deadline.is_zero() {
                Some(clock.now() + policy.round_deadline)
            } else {
                None
            };
            let live_count = sup.live.iter().filter(|&&l| l).count();
            let fanout_t0 = obs.as_ref().map(|ob| {
                ob.metrics
                    .fan_in_pending
                    .store(live_count as u64, Ordering::Relaxed);
                ob.now_ns()
            });

            // Fan-out: the same deterministic participant selection as
            // the single-thread round, split by shard ownership (the
            // supervised assignment map equals round-robin until a
            // degradation folds a dead shard's clients into survivors).
            let mut per_shard: Vec<Vec<(usize, usize)>> = vec![Vec::new(); shards];
            for (slot, &ci) in order.iter().enumerate() {
                per_shard[sup.assign[ci]].push((slot, ci));
            }
            let mut dead: Option<(usize, String, bool)> = None;
            for (s, slots) in per_shard.into_iter().enumerate() {
                if !sup.live[s] {
                    continue;
                }
                if txs[s].send(ShardCmd::Round { slots }).is_err() {
                    if !supervised {
                        return Err(shard_failure(
                            msg_rx,
                            active,
                            &format!("shard {s} disconnected"),
                        ));
                    }
                    dead = Some((s, format!("shard {s} disconnected"), false));
                    break;
                }
            }
            if let Some((s, reason, cd)) = dead {
                recover(
                    cfg, t, shards, s, reason, cd, &mut sup, txs, active, admit, msg_rx,
                    clock.as_ref(), &mut server, &mut log,
                )?;
                continue 'attempt;
            }

            // Fan-in: collect every live shard's lanes (deduplicated
            // per shard), then reduce in slot order.
            let mut tagged: Vec<(usize, RoundLane)> = Vec::with_capacity(take);
            let mut got: Vec<bool> = vec![false; shards];
            let mut done = 0usize;
            while done < live_count {
                let busy: Vec<bool> = (0..shards).map(|s| sup.live[s] && !got[s]).collect();
                match sup_wait(
                    &mut sup, txs, active, msg_rx, clock.as_ref(), &policy, &busy,
                    attempt_deadline,
                ) {
                    Ok(Waited::Msg(ShardMsg::RoundDone { shard, lanes })) => {
                        debug_assert!(shard < shards, "lanes from unknown shard {shard}");
                        if got.get(shard).copied().unwrap_or(true) {
                            continue; // a replay duplicate — already reduced
                        }
                        got[shard] = true;
                        done += 1;
                        if let (Some(ob), Some(t0)) = (&obs, fanout_t0) {
                            ob.metrics.fan_in_pending.fetch_sub(1, Ordering::Relaxed);
                            ob.metrics.observe_shard_round(
                                shard,
                                ob.now_ns().saturating_sub(t0) as f64 / 1e6,
                            );
                        }
                        tagged.extend(lanes);
                    }
                    Ok(Waited::Msg(_)) => {
                        return Err(anyhow!("unexpected shard message during round {t}"))
                    }
                    Ok(Waited::Dead {
                        shard,
                        reason,
                        conn_down,
                    }) => {
                        if !supervised {
                            return Err(anyhow!("shard {shard}: {reason}"));
                        }
                        recover(
                            cfg, t, shards, shard, reason, conn_down, &mut sup, txs, active,
                            admit, msg_rx, clock.as_ref(), &mut server, &mut log,
                        )?;
                        continue 'attempt;
                    }
                    Err(_) => {
                        return Err(shard_failure(msg_rx, active, "shards exited mid-round"))
                    }
                }
            }
            if let (Some(ob), Some(t0)) = (&obs, fanout_t0) {
                ob.span(track::COORDINATOR, "fan_in.wait", t0, live_count as i64, -1);
            }
            if tagged.len() != take {
                return Err(anyhow!(
                    "round {t}: fan-in produced {} lanes, expected {take}",
                    tagged.len()
                ));
            }
            let mut tagged = scheduler::fan_in(tagged);
            for (_, lane) in tagged.iter_mut() {
                if let Some(e) = lane.error.take() {
                    return Err(e);
                }
            }

            // Ordered reduction: metrics + FedAvg exactly as a
            // single-shard round would compute them.
            let mut m = RoundMetrics {
                round: t,
                ..Default::default()
            };
            scheduler::collect_lane_metrics(&mut m, tagged.iter().map(|(_, l)| l), &update_idx);
            let updates: Vec<&Delta> = tagged.iter().map(|(_, l)| &l.decoded).collect();
            let down_bytes_each = server.aggregate_into(&updates, &mut broadcast);
            m.down_bytes = down_bytes_each * n;

            // Broadcast + lane return; the lowest live shard evaluates
            // the synced replica.
            let mut bc = bc_slot
                .take()
                .unwrap_or_else(|| Arc::new(Delta::zeros(server.params.manifest.clone())));
            let reused = match Arc::get_mut(&mut bc) {
                Some(d) => {
                    d.copy_from(&broadcast);
                    true
                }
                None => false,
            };
            if !reused {
                bc = Arc::new(broadcast.clone());
            }
            // Encode-once APPLY: in bidirectional wire modes the
            // downstream bitstream (already produced by
            // `aggregate_into`) fans out as bytes; shards decode those
            // exact bytes back into the identical dequantized broadcast.
            let stream_arc: Option<Arc<Vec<u8>>> = match server.downstream_bytes() {
                Some(bytes) if cfg.transport.is_wire() => {
                    let mut sa = stream_slot.take().unwrap_or_default();
                    match Arc::get_mut(&mut sa) {
                        Some(v) => {
                            v.clear();
                            v.extend_from_slice(bytes);
                        }
                        None => sa = Arc::new(bytes.to_vec()),
                    }
                    Some(sa)
                }
                _ => None,
            };
            let apply_t0 = obs.as_ref().map(|ob| ob.now_ns());
            let mut back: Vec<Vec<(usize, RoundLane)>> = vec![Vec::new(); shards];
            for (slot, lane) in tagged {
                back[sup.assign[lane.client]].push((slot, lane));
            }
            let mut dead: Option<(usize, String, bool)> = None;
            for (s, lanes) in back.into_iter().enumerate() {
                if !sup.live[s] {
                    continue;
                }
                let sent = txs[s].send(ShardCmd::Apply {
                    broadcast: bc.clone(),
                    stream: stream_arc.clone(),
                    lanes,
                    eval: s == sup.eval_shard,
                });
                if sent.is_err() {
                    if !supervised {
                        return Err(shard_failure(
                            msg_rx,
                            active,
                            &format!("shard {s} disconnected"),
                        ));
                    }
                    dead = Some((s, format!("shard {s} disconnected"), false));
                    break;
                }
            }
            if let (Some(ob), Some(t0)) = (&obs, apply_t0) {
                ob.span(track::COORDINATOR, "apply.fan_out", t0, -1, -1);
            }
            if let Some((s, reason, cd)) = dead {
                recover(
                    cfg, t, shards, s, reason, cd, &mut sup, txs, active, admit, msg_rx,
                    clock.as_ref(), &mut server, &mut log,
                )?;
                continue 'attempt;
            }
            let eval_t0 = obs.as_ref().map(|ob| ob.now_ns());
            loop {
                let busy: Vec<bool> = (0..shards).map(|s| s == sup.eval_shard).collect();
                match sup_wait(
                    &mut sup, txs, active, msg_rx, clock.as_ref(), &policy, &busy,
                    attempt_deadline,
                ) {
                    Ok(Waited::Msg(ShardMsg::Eval {
                        report,
                        scale_stats,
                    })) => {
                        m.accuracy = report.accuracy;
                        m.f1 = report.f1;
                        m.test_loss = report.loss;
                        m.scale_stats = scale_stats;
                        break;
                    }
                    Ok(Waited::Msg(_)) => {
                        return Err(anyhow!("unexpected shard message awaiting eval"))
                    }
                    Ok(Waited::Dead {
                        shard,
                        reason,
                        conn_down,
                    }) => {
                        if !supervised {
                            return Err(anyhow!("shard {shard}: {reason}"));
                        }
                        recover(
                            cfg, t, shards, shard, reason, conn_down, &mut sup, txs, active,
                            admit, msg_rx, clock.as_ref(), &mut server, &mut log,
                        )?;
                        continue 'attempt;
                    }
                    Err(_) => {
                        return Err(shard_failure(msg_rx, active, "shards exited awaiting eval"))
                    }
                }
            }

            // Keep our references for reuse next round (shards drop
            // theirs once they have applied the delta / decoded the
            // stream).
            bc_slot = Some(bc);
            if let Some(sa) = stream_arc {
                stream_slot = Some(sa);
            }
            if let (Some(ob), Some(t0)) = (&obs, eval_t0) {
                ob.span(track::COORDINATOR, "eval.wait", t0, sup.eval_shard as i64, -1);
            }

            // Round-boundary client-state collect: feeds the checkpoint
            // below and (supervised, real compute) the rewind cache.
            // Still inside the attempt loop so a death here rewinds and
            // replays the whole round.
            if !(need_states || checkpoint_due) {
                break 'attempt (m, None);
            }
            if !supervised {
                let clients = collect_all_states(txs, msg_rx, active, &sup.live, "checkpoint")?;
                break 'attempt (m, Some(clients));
            }
            let mut clients: Vec<ClientState> = Vec::new();
            let mut got: Vec<bool> = vec![false; shards];
            let mut done = 0usize;
            let mut dead: Option<(usize, String, bool)> = None;
            for (s, tx) in txs.iter_mut().enumerate() {
                if !sup.live[s] {
                    continue;
                }
                let sent = tx.send(ShardCmd::State(StateCmd {
                    collect: true,
                    install: None,
                }));
                if sent.is_err() {
                    dead = Some((s, format!("shard {s} disconnected during checkpoint"), false));
                    break;
                }
            }
            while dead.is_none() && done < live_count {
                let busy: Vec<bool> = (0..shards).map(|s| sup.live[s] && !got[s]).collect();
                match sup_wait(
                    &mut sup, txs, active, msg_rx, clock.as_ref(), &policy, &busy,
                    attempt_deadline,
                ) {
                    Ok(Waited::Msg(ShardMsg::State { shard, clients: c })) => {
                        if got.get(shard).copied().unwrap_or(true) {
                            continue;
                        }
                        got[shard] = true;
                        done += 1;
                        clients.extend(c);
                    }
                    Ok(Waited::Msg(_)) => {
                        return Err(anyhow!("unexpected shard message during checkpoint"))
                    }
                    Ok(Waited::Dead {
                        shard,
                        reason,
                        conn_down,
                    }) => {
                        dead = Some((shard, reason, conn_down));
                    }
                    Err(e) => return Err(e),
                }
            }
            if let Some((s, reason, cd)) = dead {
                recover(
                    cfg, t, shards, s, reason, cd, &mut sup, txs, active, admit, msg_rx,
                    clock.as_ref(), &mut server, &mut log,
                )?;
                continue 'attempt;
            }
            clients.sort_by_key(|c| c.id);
            break 'attempt (m, Some(clients));
        };

        let acc = m.accuracy;
        if let Some(ob) = &obs {
            ob.metrics.record_round(&m);
        }
        log.push(m);

        // ---- checkpoint: one atomic snapshot from the round-boundary
        //      collect (before the round event fires, so an observed
        //      round line implies its snapshot is on disk) ----
        if checkpoint_due {
            if let (Some(store), Some(clients)) = (&session.store, collected.as_ref()) {
                let snap = SessionState {
                    cfg: cfg.clone(),
                    synthetic: session.synthetic,
                    next_round: t + 1,
                    shards,
                    manifest_tsv: server.params.manifest.to_tsv(),
                    params: SessionState::bundle_params(&server.params),
                    rounds: log.rounds.clone(),
                    clients: clients.clone(),
                };
                let ckpt_t0 = obs.as_ref().map(|ob| ob.now_ns());
                store.write(&snap)?;
                if let (Some(ob), Some(t0)) = (&obs, ckpt_t0) {
                    ob.span(track::SESSION, "checkpoint.write", t0, t as i64, -1);
                }
            }
        }

        // Advance the rewind target to the round-(t+1) boundary: round
        // t is final, so recovery never replays across it.
        if supervised {
            sup.cache.rounds_done = t + 1;
            sup.cache.params.copy_from(&server.params);
            if need_states {
                sup.cache.clients = collected.unwrap_or_default();
            }
        }

        let done = log
            .rounds
            .last()
            .ok_or_else(|| anyhow!("round log empty after recording round {t}"))?;
        on_event(&Event::RoundDone(done.clone()));

        if let (Some(ob), Some(t0)) = (&obs, round_t0) {
            ob.span(track::COORDINATOR, "round", t0, -1, -1);
            ob.bridge_events(&log.events);
        }

        // Fault injection for the session test plane: an in-process
        // stand-in for `kill -9` right after round t's checkpoint.
        if session.crash_after == Some(t) {
            return Err(anyhow!(
                "session: injected crash after round {t} (crash_after)"
            ));
        }

        if let Some(target) = cfg.target_accuracy {
            if acc >= target {
                break;
            }
        }
    }
    if let Some(ob) = &obs {
        // Catch incidents recorded after the last round span closed
        // and park subsequent instants outside any round.
        ob.bridge_events(&log.events);
        ob.set_round(-1);
    }
    Ok(log)
}

// ---------------------------------------------------------------------------
// Shard workers
// ---------------------------------------------------------------------------

/// One shard's compute + eval capability, abstracted over real
/// PJRT-backed clients vs the synthetic plane so every transport loop
/// drives both identically.
trait ShardBody {
    /// The model contract this shard serves.
    fn manifest(&self) -> Arc<Manifest>;
    /// Initial model parameters (sent in the READY handshake).
    fn init_params(&self) -> ParamSet;
    /// Run one round's compute + codec stages over `lanes` (one per
    /// local participant; `order[k]` is the global client id of slot k).
    fn run_round(&mut self, order: &[usize], lanes: &mut Vec<RoundLane>) -> Result<()>;
    /// Apply the aggregated broadcast to every local replica.
    fn apply(&mut self, broadcast: &Delta) -> Result<()>;
    /// Evaluate the central model on the synced replica (shard 0 only).
    fn eval(&mut self) -> Result<(EvalReport, Vec<ScaleStats>)>;
    /// Export every local client's round-boundary state (session
    /// plane; empty on the synthetic plane). Includes paged-out
    /// clients, rehydrated from the spill store — which can fail, so
    /// the export is fallible.
    fn collect_state(&mut self) -> Result<Vec<ClientState>>;
    /// Install a [`StateInstall`]: re-assignment, absolute replica
    /// parameters, fast-forwarded round counter and client states.
    fn install_state(&mut self, inst: &StateInstall) -> Result<()>;
}

/// Per-shard codec pool width: auto-sized pools split the machine
/// between shards instead of each grabbing full parallelism (N shards ×
/// ncpu codec threads would just thrash); explicit widths are per-shard
/// as documented.
fn shard_pool(cfg: &ExperimentConfig, shards: usize) -> WorkerPool {
    if cfg.codec_workers == 0 {
        let auto = WorkerPool::new(0).workers();
        WorkerPool::new((auto / shards).max(1))
    } else {
        WorkerPool::new(cfg.codec_workers)
    }
}

/// [`ShardBody`] over real PJRT-backed clients (the production shape).
struct RealShard<'a, 'rt> {
    mr: &'a ModelRuntime<'rt>,
    cfg: &'a ExperimentConfig,
    shard: usize,
    shards: usize,
    clients: Vec<Client>,
    train_data: Dataset,
    test_batches: Vec<Batch>,
    manifest: Arc<Manifest>,
    pcfg: ProtocolConfig,
    update_idx: Vec<usize>,
    scale_idx: Vec<usize>,
    pool: WorkerPool,
    mode: ScheduleMode,
    init: ParamSet,
    /// Cold-state spill store (`--resident-clients`); `None` when every
    /// client stays resident.
    pager: Option<ClientPager>,
    /// Resident budget (0 = paging off). At least one client is always
    /// kept resident — it donates the post-broadcast replica to
    /// rehydrated clients and serves eval.
    budget: usize,
    /// Telemetry handle (codec-stage spans, pager spans, residency
    /// gauges). `None` on untraced shards (e.g. wire workers).
    obs: Obs,
}

impl<'a, 'rt> RealShard<'a, 'rt> {
    fn build(
        mr: &'a ModelRuntime<'rt>,
        cfg: &'a ExperimentConfig,
        shard: usize,
        shards: usize,
        obs: Obs,
    ) -> Result<Self> {
        // Identical deterministic substrate on every shard; only the
        // round-robin-owned clients are instantiated here.
        let setup = build_setup(mr, cfg, |ci| scheduler::shard_of(ci, shards) == shard)?;
        let manifest = mr.manifest.clone();
        // Cold-state paging: with a resident budget set, clients beyond
        // it spill through the session snapshot codec and rehydrate on
        // selection (see `session::pager`). The spill dir rides the
        // session dir when one is configured (inspectable, but still
        // ephemeral per run); otherwise a per-process temp dir the
        // pager garbage-collects on drop.
        let pager = if cfg.resident_clients > 0 {
            let dir = match &cfg.session {
                Some(s) => s.dir.join(format!("pages-shard-{shard}")),
                None => std::env::temp_dir()
                    .join(format!("fsfl-pages-{}-{shard}", std::process::id())),
            };
            Some(ClientPager::open(dir)?)
        } else {
            None
        };
        let mut built = Self {
            mr,
            cfg,
            shard,
            shards,
            clients: setup.clients,
            train_data: setup.train_data,
            test_batches: setup.test_batches,
            pcfg: cfg.protocol_config(),
            update_idx: manifest.update_indices(),
            scale_idx: manifest.group_indices(Group::Scale),
            pool: shard_pool(cfg, shards),
            mode: cfg.schedule_mode(),
            manifest,
            init: setup.init,
            pager,
            budget: cfg.resident_clients,
            obs,
        };
        // Residency gauges start from the fully-built set; the
        // immediate evict below moves the cold share to `paged`.
        if let Some(t) = &built.obs {
            t.metrics
                .resident_clients
                .fetch_add(built.clients.len() as u64, Ordering::Relaxed);
        }
        // Enforce the budget from round 0 (the build itself still
        // instantiates the full owned set; spilling is immediate).
        built.evict_cold(&[])?;
        Ok(built)
    }

    /// Rehydrate every paged-out participant of `order` before the
    /// round runs. One [`build_setup`] call reconstructs the
    /// deterministic substrate objects for exactly the missing ids
    /// (warmup skipped — its only effect is the initial params, which
    /// the replica copy below overwrites), then each client takes a
    /// resident donor's replica (all replicas are equal at a round
    /// boundary) and its own spilled round-boundary state.
    fn page_in(&mut self, order: &[usize]) -> Result<()> {
        let Some(mut pager) = self.pager.take() else {
            return Ok(());
        };
        let res = self.page_in_from(&mut pager, order);
        self.pager = Some(pager);
        res
    }

    fn page_in_from(&mut self, pager: &mut ClientPager, order: &[usize]) -> Result<()> {
        let missing: std::collections::BTreeSet<usize> = order
            .iter()
            .copied()
            .filter(|&ci| pager.contains(ci))
            .collect();
        if missing.is_empty() {
            return Ok(());
        }
        let t0 = self.obs.as_ref().map(|t| t.now_ns());
        let donor_global = self
            .clients
            .first()
            .ok_or_else(|| anyhow!("paging requires at least one resident client"))?
            .global
            .clone();
        let mut rebuild_cfg = self.cfg.clone();
        rebuild_cfg.warmup_steps = 0;
        let setup = build_setup(self.mr, &rebuild_cfg, |ci| missing.contains(&ci))?;
        let rehydrated = setup.clients.len() as u64;
        for mut c in setup.clients {
            let st = pager.take(c.id)?;
            c.global.copy_from(&donor_global);
            c.import_state(&st)?;
            self.clients.push(c);
        }
        if let (Some(t), Some(t0)) = (&self.obs, t0) {
            t.metrics
                .resident_clients
                .fetch_add(rehydrated, Ordering::Relaxed);
            t.metrics.paged_clients.fetch_sub(rehydrated, Ordering::Relaxed);
            t.span(track::SESSION, "pager.page_in", t0, rehydrated as i64, -1);
        }
        Ok(())
    }

    /// Enforce the resident budget after a round: this round's
    /// participants (`used`) are the warmest, so non-participants spill
    /// first (round-granularity LRU). At least one client always stays
    /// resident — the replica donor for the next page-in and the eval
    /// replica. Which clients spill never changes outputs (spilled
    /// state is exact and replicas are interchangeable post-broadcast);
    /// the paging legs of `tests/integration_session.rs` pin this.
    fn evict_cold(&mut self, used: &[usize]) -> Result<()> {
        let Some(mut pager) = self.pager.take() else {
            return Ok(());
        };
        let t0 = self.obs.as_ref().map(|t| t.now_ns());
        let mut spilled = 0u64;
        let res = (|| {
            let target = self.budget.max(1);
            if self.clients.len() > target {
                let warm: std::collections::BTreeSet<usize> =
                    used.iter().copied().collect();
                // Stable sort: cold (non-participant) clients sink to
                // the front and spill first.
                self.clients.sort_by_key(|c| warm.contains(&c.id));
                while self.clients.len() > target {
                    let c = self.clients.remove(0);
                    pager.store(&c.export_state())?;
                    spilled += 1;
                }
            }
            Ok(())
        })();
        self.pager = Some(pager);
        if let (Some(t), Some(t0)) = (&self.obs, t0) {
            if spilled > 0 {
                t.metrics.resident_clients.fetch_sub(spilled, Ordering::Relaxed);
                t.metrics.paged_clients.fetch_add(spilled, Ordering::Relaxed);
            }
            t.span(track::SESSION, "pager.evict", t0, spilled as i64, -1);
        }
        res
    }
}

impl ShardBody for RealShard<'_, '_> {
    fn manifest(&self) -> Arc<Manifest> {
        self.manifest.clone()
    }

    fn init_params(&self) -> ParamSet {
        self.init.clone()
    }

    fn run_round(&mut self, order: &[usize], lanes: &mut Vec<RoundLane>) -> Result<()> {
        // Paging bracket: rehydrate this round's cohort, run, then
        // spill back down to the budget. Spilling before APPLY is safe
        // because a client's exportable state excludes the global
        // replica — a rehydrated client takes a resident donor's.
        self.page_in(order)?;
        // The same ComputePlane glue the single-process Experiment uses,
        // with round-robin local indexing (the compute plane falls back
        // to an id search when paging reorders the local set).
        let mut compute = ExperimentCompute {
            mr: self.mr,
            clients: &mut self.clients,
            shards: self.shards,
            train_data: &self.train_data,
            cfg: self.cfg,
            pcfg: &self.pcfg,
        };
        scheduler::run_round_observed(
            self.mode,
            &self.pool,
            &mut compute,
            lanes,
            order,
            &self.pcfg,
            &self.update_idx,
            &self.scale_idx,
            self.obs.as_deref(),
        )?;
        self.evict_cold(order)
    }

    fn apply(&mut self, broadcast: &Delta) -> Result<()> {
        for c in self.clients.iter_mut() {
            c.apply_broadcast(broadcast);
        }
        Ok(())
    }

    fn eval(&mut self) -> Result<(EvalReport, Vec<ScaleStats>)> {
        // Post-broadcast, every replica equals the server model;
        // evaluate on this shard's first client (global client 0 lives
        // on shard 0).
        let replica = &self
            .clients
            .first()
            .ok_or_else(|| anyhow!("eval shard owns no clients"))?
            .global;
        let report = evaluate_params(self.mr, replica, &self.test_batches)?;
        let scale_stats = if self.pcfg.scaled {
            self.clients[0]
                .scale_values()
                .into_iter()
                .map(|(layer, vals)| ScaleStats::from_values(&layer, &vals))
                .collect()
        } else {
            Vec::new()
        };
        Ok((report, scale_stats))
    }

    fn collect_state(&mut self) -> Result<Vec<ClientState>> {
        let mut states: Vec<ClientState> =
            self.clients.iter().map(|c| c.export_state()).collect();
        if let Some(pager) = &mut self.pager {
            // Spilled states are already round-boundary exports — load
            // them verbatim (they stay spilled; a collect is a read).
            let spilled: Vec<usize> = pager.ids().collect();
            for id in spilled {
                states.push(pager.load(id)?);
            }
        }
        states.sort_by_key(|c| c.id);
        Ok(states)
    }

    fn install_state(&mut self, inst: &StateInstall) -> Result<()> {
        if inst.params.numel() != self.init.numel() {
            return Err(anyhow!(
                "state params carry {} values, model has {}",
                inst.params.numel(),
                self.init.numel()
            ));
        }
        // Cross-index reassignment never happens: resume installs each
        // shard's own index, elastic replacement admits the newcomer
        // under the departed index, and a resize keeps every surviving
        // worker's index (the per-connection readers validate shard
        // identity, so a silently re-indexed worker would be rejected
        // anyway). Reject an index change instead of guessing.
        if inst.shard != self.shard {
            return Err(anyhow!(
                "state install re-assigns this worker from shard {} to {}; \
                 cross-index reassignment is not supported (replacement workers \
                 re-join under the departed index)",
                self.shard,
                inst.shard
            ));
        }
        // Unified ownership resolution — ONE wanted id set, with the
        // coordinator's explicit migrated set dominating arithmetic:
        //   · a non-empty migrated set IS the ownership (quorum
        //     degradation folds a dead shard's clients into survivors,
        //     so re-deriving round-robin here would drift from what the
        //     coordinator installed — the PR-6 `local_of` bug class);
        //   · an empty set with a changed shard *count* is an elastic
        //     resize under round-robin;
        //   · otherwise ownership is unchanged.
        // A rebuild reconstructs the local set from the shared
        // deterministic substrate. The recycled lane scratch stays
        // valid (lanes are manifest-shaped, not assignment-shaped) and
        // the codec pool keeps its width — width never changes outputs.
        // Warmup is skipped: it only shapes the *initial* params, which
        // the absolute install below overwrites bit-for-bit (datasets,
        // splits and schedules do not depend on it), so the rebuild
        // pays no PJRT train steps.
        let want: Option<std::collections::BTreeSet<usize>> = if !inst.clients.is_empty() {
            Some(inst.clients.iter().map(|s| s.id).collect())
        } else if inst.shards != self.shards {
            Some(
                (0..self.cfg.clients)
                    .filter(|&ci| scheduler::shard_of(ci, inst.shards) == inst.shard)
                    .collect(),
            )
        } else {
            None
        };
        match want {
            Some(ids) => {
                // Resident ids only: with paging on, a wanted-but-spilled
                // client must be rebuilt resident too (its spill predates
                // the install and is cleared below).
                let local: std::collections::BTreeSet<usize> =
                    self.clients.iter().map(|c| c.id).collect();
                if ids != local {
                    let mut rebuild_cfg = self.cfg.clone();
                    rebuild_cfg.warmup_steps = 0;
                    let setup = build_setup(self.mr, &rebuild_cfg, |ci| ids.contains(&ci))?;
                    let old = self.clients.len() as u64;
                    self.clients = setup.clients;
                    if let Some(t) = &self.obs {
                        // Registry gauges are shared across shards, so
                        // residency changes apply as deltas.
                        let new = self.clients.len() as u64;
                        if new >= old {
                            t.metrics.resident_clients.fetch_add(new - old, Ordering::Relaxed);
                        } else {
                            t.metrics.resident_clients.fetch_sub(old - new, Ordering::Relaxed);
                        }
                    }
                }
            }
            None => {
                // Ownership unchanged: rehydrate every spilled client
                // (per-client state carries over verbatim) so the
                // absolute replica install below reaches them too.
                let spilled: Vec<usize> = self
                    .pager
                    .as_ref()
                    .map(|p| p.ids().collect())
                    .unwrap_or_default();
                if !spilled.is_empty() {
                    self.page_in(&spilled)?;
                }
            }
        }
        self.shards = inst.shards;
        // Absolute replica state: every local client equals the server.
        for c in self.clients.iter_mut() {
            c.global.copy_from(&inst.params);
        }
        if !inst.clients.is_empty() {
            for c in self.clients.iter_mut() {
                let st = inst
                    .clients
                    .iter()
                    .find(|s| s.id == c.id)
                    .ok_or_else(|| {
                        anyhow!("no migrated state for locally-owned client {}", c.id)
                    })?;
                c.import_state(st)?;
            }
        }
        // The install is absolute: whatever was spilled before it is
        // stale. Drop it all, then re-enforce the resident budget.
        if let Some(pager) = &mut self.pager {
            if let Some(t) = &self.obs {
                let stale = pager.ids().count() as u64;
                t.metrics.paged_clients.fetch_sub(stale, Ordering::Relaxed);
            }
            pager.clear()?;
        }
        self.evict_cold(&[])
    }
}

/// [`ShardBody`] over [`SyntheticPlane`]: protocol-complete, PJRT-free.
/// Tracks the accumulated broadcast history so its `eval` (see
/// [`synth_eval`]) is a pure function of every aggregated byte.
struct SynthShard {
    plane: SyntheticPlane,
    pool: WorkerPool,
    pcfg: ProtocolConfig,
    update_idx: Vec<usize>,
    scale_idx: Vec<usize>,
    mode: ScheduleMode,
    seed: u64,
    round: u64,
    accum: Delta,
    /// Telemetry handle (codec-stage spans). `None` on untraced shards.
    obs: Obs,
}

impl SynthShard {
    fn new(manifest: Arc<Manifest>, cfg: &ExperimentConfig, shards: usize, obs: Obs) -> Self {
        let pcfg = cfg.protocol_config();
        Self {
            plane: SyntheticPlane {
                manifest: manifest.clone(),
                round_seed: 0,
                scaled: pcfg.scaled,
                // Env rather than config so `--shard-procs` workers
                // inherit the bench straggler schedule automatically.
                straggle: crate::fl::synth::straggle_from_env(),
            },
            pool: shard_pool(cfg, shards),
            pcfg,
            update_idx: manifest.update_indices(),
            scale_idx: manifest.group_indices(Group::Scale),
            mode: cfg.schedule_mode(),
            seed: cfg.seed,
            round: 0,
            accum: Delta::zeros(manifest),
            obs,
        }
    }
}

impl ShardBody for SynthShard {
    fn manifest(&self) -> Arc<Manifest> {
        self.plane.manifest.clone()
    }

    fn init_params(&self) -> ParamSet {
        let m = self.plane.manifest.clone();
        let tensors = m.tensors.iter().map(|t| vec![0.0f32; t.numel()]).collect();
        // fsfl-lint: allow(panic): zeros are built from the manifest itself, so the shape check cannot fail; the trait returns a bare ParamSet
        ParamSet::new(m, tensors).expect("zero params match their own manifest")
    }

    fn run_round(&mut self, order: &[usize], lanes: &mut Vec<RoundLane>) -> Result<()> {
        // Every shard sees every ROUND command (empty slot sets
        // included), so a local counter stays globally consistent.
        self.plane.round_seed = self
            .seed
            .wrapping_add((self.round + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.round += 1;
        scheduler::run_round_observed(
            self.mode,
            &self.pool,
            &mut self.plane,
            lanes,
            order,
            &self.pcfg,
            &self.update_idx,
            &self.scale_idx,
            self.obs.as_deref(),
        )
    }

    fn apply(&mut self, broadcast: &Delta) -> Result<()> {
        self.accum.accumulate(broadcast);
        Ok(())
    }

    fn eval(&mut self) -> Result<(EvalReport, Vec<ScaleStats>)> {
        Ok((synth_eval(&self.accum), Vec::new()))
    }

    fn collect_state(&mut self) -> Result<Vec<ClientState>> {
        // The synthetic plane carries no per-client state: a client's
        // output is a pure function of (round seed, id).
        Ok(Vec::new())
    }

    fn install_state(&mut self, inst: &StateInstall) -> Result<()> {
        // The synthetic init is all-zero, so the absolute server params
        // equal the sequential broadcast sum bit for bit — installing
        // them into `accum` reproduces the uninterrupted eval exactly.
        if inst.params.tensors.len() != self.accum.tensors.len() {
            return Err(anyhow!(
                "state params carry {} tensors, synth plane has {}",
                inst.params.tensors.len(),
                self.accum.tensors.len()
            ));
        }
        for (i, (a, p)) in self
            .accum
            .tensors
            .iter_mut()
            .zip(&inst.params.tensors)
            .enumerate()
        {
            if a.len() != p.len() {
                return Err(anyhow!(
                    "state params tensor {i}: {} values, synth plane wants {}",
                    p.len(),
                    a.len()
                ));
            }
            a.copy_from_slice(p);
        }
        self.round = inst.rounds_done;
        Ok(())
    }
}

/// The round-serving loop over typed mpsc channels (lanes move to the
/// coordinator and come back for recycling in `Apply`). `chaos`
/// scripts at most one fault-injected death for the recovery tests;
/// production admissions pass `None`.
fn shard_loop_mpsc(
    body: &mut dyn ShardBody,
    shard: usize,
    chaos: Option<ChaosDeath>,
    cmd_rx: &mpsc::Receiver<ShardCmd>,
    msg_tx: &mpsc::Sender<ShardMsg>,
) -> Result<()> {
    let manifest = body.manifest();
    msg_tx
        .send(ShardMsg::Ready {
            shard,
            init: body.init_params(),
        })
        .map_err(|_| anyhow!("coordinator disconnected"))?;

    // Recycled lanes: grown to this shard's per-round watermark.
    let mut free: Vec<RoundLane> = Vec::new();
    let mut lanes: Vec<RoundLane> = Vec::new();
    let mut rounds_seen = 0usize;
    loop {
        match cmd_rx.recv() {
            Ok(ShardCmd::Round { slots }) => {
                if let Some(cd) = &chaos {
                    if cd.round == rounds_seen {
                        match cd.point {
                            // Silent death: no FAILED message — only the
                            // supervisor's liveness machinery can notice.
                            ChaosPoint::MidRound => return Ok(()),
                            // A stall keeps the channel open but never
                            // answers anything again (heartbeats
                            // included), until the coordinator lets go.
                            ChaosPoint::Stall => {
                                while cmd_rx.recv().is_ok() {}
                                return Ok(());
                            }
                            ChaosPoint::MidCollect => {}
                        }
                    }
                }
                rounds_seen += 1;
                let order: Vec<usize> = slots.iter().map(|&(_, ci)| ci).collect();
                while free.len() < order.len() {
                    free.push(RoundLane::new(manifest.clone()));
                }
                lanes.clear();
                let keep = free.len() - order.len();
                lanes.extend(free.drain(keep..));
                body.run_round(&order, &mut lanes)?;
                let tagged: Vec<(usize, RoundLane)> = slots
                    .iter()
                    .map(|&(slot, _)| slot)
                    .zip(lanes.drain(..))
                    .collect();
                msg_tx
                    .send(ShardMsg::RoundDone {
                        shard,
                        lanes: tagged,
                    })
                    .map_err(|_| anyhow!("coordinator disconnected"))?;
            }
            Ok(ShardCmd::Apply {
                broadcast,
                stream: _,
                lanes: returned,
                eval,
            }) => {
                body.apply(&broadcast)?;
                free.extend(returned.into_iter().map(|(_, l)| l));
                if eval {
                    let (report, scale_stats) = body.eval()?;
                    msg_tx
                        .send(ShardMsg::Eval {
                            report,
                            scale_stats,
                        })
                        .map_err(|_| anyhow!("coordinator disconnected"))?;
                }
            }
            Ok(ShardCmd::State(cmd)) => {
                if cmd.collect {
                    if let Some(cd) = &chaos {
                        if cd.point == ChaosPoint::MidCollect && rounds_seen == cd.round + 1 {
                            return Ok(()); // silent death mid STATE collect
                        }
                    }
                }
                if let Some(inst) = &cmd.install {
                    body.install_state(inst)?;
                }
                if cmd.collect {
                    let clients = body.collect_state()?;
                    msg_tx
                        .send(ShardMsg::State { shard, clients })
                        .map_err(|_| anyhow!("coordinator disconnected"))?;
                }
            }
            Ok(ShardCmd::Heartbeat { nonce }) => {
                msg_tx
                    .send(ShardMsg::Heartbeat { shard, nonce })
                    .map_err(|_| anyhow!("coordinator disconnected"))?;
            }
            Ok(ShardCmd::Stop) | Err(_) => break,
        }
    }
    Ok(())
}

/// The round-serving loop over a wire connection: commands are decoded
/// frames, lanes are serialized out and recycled locally (they never
/// come back), the broadcast is deserialized into one recycled buffer
/// (dense) or decoded from the once-encoded downstream stream
/// (bidirectional).
fn shard_loop_wire(
    body: &mut dyn ShardBody,
    shard: usize,
    chaos: Option<ChaosDeath>,
    sink: &mut FrameSink,
    source: &mut FrameSource,
    downstream: Option<crate::compression::UpdateCodec>,
) -> Result<()> {
    let manifest = body.manifest();
    let mut out = Vec::new();
    wire::encode_ready(&mut out, shard, &body.init_params());
    sink.send(&out)
        .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;

    let mut free: Vec<RoundLane> = Vec::new();
    let mut lanes: Vec<RoundLane> = Vec::new();
    let mut bcast = Delta::zeros(manifest.clone());
    let mut scratch = crate::compression::CodecScratch::default();
    let mut inbuf = Vec::new();
    let mut rounds_seen = 0usize;
    loop {
        // A *closed* inbound link is the wire analogue of the mpsc recv
        // error: the coordinator is gone, wind down quietly. A *corrupt*
        // frame is a real fault — propagate it so the FAILED path runs
        // (best effort) and the worker exits nonzero instead of
        // masquerading as a clean shutdown.
        match source.recv(&mut inbuf) {
            Ok(true) => {}
            Ok(false) => break,
            Err(e) => return Err(anyhow!("command receive failed: {e:#}")),
        }
        match wire::cmd_tag(&inbuf)? {
            CmdTag::Init => return Err(anyhow!("unexpected second INIT handshake")),
            CmdTag::Round => {
                let slots = wire::decode_round(&inbuf)?;
                if let Some(cd) = &chaos {
                    if cd.round == rounds_seen {
                        match cd.point {
                            // Silent death: drop the connection without a
                            // FAILED frame — the reader surfaces ConnDown.
                            ChaosPoint::MidRound => return Ok(()),
                            // Stall: hold the link open, answer nothing
                            // (not even heartbeats) until it closes.
                            ChaosPoint::Stall => {
                                while matches!(source.recv(&mut inbuf), Ok(true)) {}
                                return Ok(());
                            }
                            ChaosPoint::MidCollect => {}
                        }
                    }
                }
                rounds_seen += 1;
                let order: Vec<usize> = slots.iter().map(|&(_, ci)| ci).collect();
                while free.len() < order.len() {
                    free.push(RoundLane::new(manifest.clone()));
                }
                lanes.clear();
                let keep = free.len() - order.len();
                lanes.extend(free.drain(keep..));
                body.run_round(&order, &mut lanes)?;
                let tagged: Vec<(usize, RoundLane)> = slots
                    .iter()
                    .map(|&(slot, _)| slot)
                    .zip(lanes.drain(..))
                    .collect();
                wire::encode_round_done(&mut out, shard, &tagged)?;
                sink.send(&out)
                    .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;
                // Lanes recycle locally — only their bytes crossed.
                free.extend(tagged.into_iter().map(|(_, l)| l));
            }
            CmdTag::Apply => {
                let eval =
                    wire::decode_apply_into(&inbuf, &mut bcast, downstream.as_ref(), &mut scratch)?;
                body.apply(&bcast)?;
                if eval {
                    let (report, scale_stats) = body.eval()?;
                    wire::encode_eval(&mut out, &report, &scale_stats);
                    sink.send(&out)
                        .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;
                }
            }
            CmdTag::State => {
                let cmd = wire::decode_state_cmd(&inbuf, &manifest)?;
                if cmd.collect {
                    if let Some(cd) = &chaos {
                        if cd.point == ChaosPoint::MidCollect && rounds_seen == cd.round + 1 {
                            return Ok(()); // silent death mid STATE collect
                        }
                    }
                }
                if let Some(inst) = &cmd.install {
                    body.install_state(inst)?;
                }
                if cmd.collect {
                    let states = body.collect_state()?;
                    wire::encode_state_msg(&mut out, shard, &states);
                    sink.send(&out)
                        .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;
                }
            }
            CmdTag::Heartbeat => {
                let nonce = wire::decode_heartbeat_cmd(&inbuf)?;
                wire::encode_heartbeat_msg(&mut out, shard, nonce);
                sink.send(&out)
                    .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;
            }
            CmdTag::Stop => break,
        }
    }
    Ok(())
}

/// Build the [`ShardBody`] a decoded INIT asks for and serve the wire
/// loop with it. `Real` needs a PJRT runtime + artifacts; `Synthetic`
/// needs neither.
fn run_shard_body(
    init: &wire::Init,
    chaos: Option<ChaosDeath>,
    sink: &mut FrameSink,
    source: &mut FrameSource,
) -> Result<()> {
    let downstream = init.cfg.downstream_codec();
    match &init.compute {
        ComputeSpec::Real => {
            let rt = Runtime::cpu()?;
            let mr = ModelRuntime::open(&rt, &init.cfg.artifacts_root, &init.cfg.variant)?;
            // Wire workers run outside the coordinator's trace: their
            // codec stages would need a cross-process clock to land on
            // the coordinator timeline, so they stay untraced (the
            // coordinator-side frame endpoints still count and trace
            // every byte they exchange).
            let mut body = RealShard::build(&mr, &init.cfg, init.shard, init.shards, None)?;
            shard_loop_wire(&mut body, init.shard, chaos, sink, source, downstream)
        }
        ComputeSpec::Synthetic { manifest } => {
            let mut body = SynthShard::new(manifest.clone(), &init.cfg, init.shards, None);
            shard_loop_wire(&mut body, init.shard, chaos, sink, source, downstream)
        }
    }
}

/// Serve one shard over an established transport connection: INIT
/// handshake in, then the round loop until STOP or disconnect. A fatal
/// error is reported back as a FAILED frame (best effort) before
/// returning it.
fn serve_shard_transport(transport: Box<dyn Transport>) -> Result<()> {
    serve_shard_transport_with(transport, None)
}

/// [`serve_shard_transport`] with a scripted chaos death (the in-process
/// loopback/TCP admission path threads fault injection through here —
/// chaos deaths are deliberately *silent*: the FAILED frame only covers
/// real errors, so the supervisor must detect the loss itself).
fn serve_shard_transport_with(
    transport: Box<dyn Transport>,
    chaos: Option<ChaosDeath>,
) -> Result<()> {
    let (mut sink, mut source) = transport.open()?;
    let mut buf = Vec::new();
    match source.recv(&mut buf) {
        Ok(true) => {}
        Ok(false) => return Err(anyhow!("coordinator closed before INIT")),
        Err(e) => return Err(anyhow!("INIT receive failed: {e:#}")),
    }
    if !matches!(wire::cmd_tag(&buf)?, CmdTag::Init) {
        return Err(anyhow!("expected INIT handshake first"));
    }
    let init = wire::decode_init(&buf)?;
    let shard = init.shard;
    let result = run_shard_body(&init, chaos, &mut sink, &mut source);
    if let Err(e) = &result {
        let mut out = Vec::new();
        wire::encode_failed(&mut out, shard, &format!("{e:#}"));
        let _ = sink.send(&out);
    }
    result
}

// ---------------------------------------------------------------------------
// Hierarchical tree fan-in: mid-tier aggregators
// ---------------------------------------------------------------------------

/// Serve one **mid-tier aggregator** over an established upstream
/// transport connection: receive the ordinary shard INIT, spawn
/// `children` leaf shard workers over in-process loopback pipes, and
/// relay the round protocol between them — reducing the subtree's
/// ROUND_DONE lanes through the same associative, slot-ordered
/// [`scheduler::fan_in`] the coordinator uses into ONE merged upstream
/// frame.
///
/// From the coordinator's point of view an aggregator **is** a shard:
/// it answers READY / ROUND_DONE / EVAL / STATE / HEARTBEAT under its
/// own index `a` of `A` top-level slots, and the coordinator needs no
/// topology awareness at all. Internally, child `j` is initialized as
/// global leaf shard `a + A·j` of `A·children` leaves, so the union of
/// the children's round-robin client sets is exactly `{c : c mod A ==
/// a}` — the aggregator's own slot set — and every client lands on one
/// deterministic leaf (`child_of(c) = (c / A) mod children`).
///
/// Determinism: `fan_in` sorts lanes by global round slot, so reducing
/// per-subtree before the coordinator's final reduction reassociates
/// but never reorders the aggregation — the coordinator decodes a lane
/// sequence byte-identical to the flat fan-in. A depth-1 tree
/// (`children == 1`) relays frames essentially verbatim, and every
/// deeper shape pins the same `RunLog` rounds
/// (`tests/integration_tree.rs`). Only coordinator↔aggregator frames
/// count toward [`RunLog::wire`]; subtree-internal loopback traffic is
/// topology-private.
pub fn serve_aggregator_transport(upstream: Box<dyn Transport>, children: usize) -> Result<()> {
    let (mut sink, mut source) = upstream.open()?;
    let mut buf = Vec::new();
    match source.recv(&mut buf) {
        Ok(true) => {}
        Ok(false) => return Err(anyhow!("coordinator closed before INIT")),
        Err(e) => return Err(anyhow!("INIT receive failed: {e:#}")),
    }
    if !matches!(wire::cmd_tag(&buf)?, CmdTag::Init) {
        return Err(anyhow!("expected INIT handshake first"));
    }
    let init = wire::decode_init(&buf)?;
    let shard = init.shard;
    let result = run_aggregator(&init, children.max(1), &mut sink, &mut source);
    if let Err(e) = &result {
        let mut out = Vec::new();
        wire::encode_failed(&mut out, shard, &format!("{e:#}"));
        let _ = sink.send(&out);
    }
    result
}

/// Receive child `j`'s next frame into `buf`. A closed pipe or a FAILED
/// frame becomes a descriptive error (tagged with the failing leaf's
/// global index) — the upstream FAILED relay happens in
/// [`serve_aggregator_transport`]'s error path.
fn recv_child(source: &mut FrameSource, buf: &mut Vec<u8>, j: usize) -> Result<MsgTag> {
    match source.recv(buf) {
        Ok(true) => {}
        Ok(false) => return Err(anyhow!("subtree child {j} closed its pipe")),
        Err(e) => return Err(anyhow!("subtree child {j}: receive failed: {e:#}")),
    }
    let tag = wire::msg_tag(buf)?;
    if matches!(tag, MsgTag::Failed) {
        let (leaf, msg) = wire::decode_failed(buf)?;
        return Err(anyhow!("subtree leaf shard {leaf}: {msg}"));
    }
    Ok(tag)
}

/// The aggregator relay loop (see [`serve_aggregator_transport`] for
/// the topology and determinism contract).
fn run_aggregator(
    init: &wire::Init,
    children: usize,
    up_sink: &mut FrameSink,
    up_source: &mut FrameSource,
) -> Result<()> {
    let a = init.shard;
    let top = init.shards;
    let leaves = top * children;
    let mut out = Vec::new();
    let mut inbuf = Vec::new();
    let mut buf = Vec::new();

    // Spawn the subtree: child j serves global leaf shard a + top*j
    // over an internal loopback pipe. The INIT config is forwarded
    // verbatim — leaves ignore `tree_children`; the INIT's own
    // shard/shards fields carry the leaf indexing.
    let mut kids: Vec<(FrameSink, FrameSource)> = Vec::with_capacity(children);
    let mut handles = Vec::with_capacity(children);
    for j in 0..children {
        let (agg_end, leaf_end) = loopback_pair();
        handles.push(std::thread::spawn(move || {
            serve_shard_transport(Box::new(leaf_end))
        }));
        let (mut k_sink, k_source) = (Box::new(agg_end) as Box<dyn Transport>).open()?;
        wire::encode_init(&mut out, a + top * j, leaves, &init.cfg, &init.compute);
        k_sink
            .send(&out)
            .map_err(|e| anyhow!("subtree child {j}: {e:#}"))?;
        kids.push((k_sink, k_source));
    }

    // Startup barrier: every child builds its plane and reports READY.
    // The deterministic substrate makes every leaf's init params
    // identical, so child 0's READY becomes the subtree's upstream
    // READY.
    let mut init_params: Option<ParamSet> = None;
    for j in 0..children {
        match recv_child(&mut kids[j].1, &mut buf, j)? {
            MsgTag::Ready => {
                let (leaf, params) = wire::decode_ready(&buf)?;
                if leaf != a + top * j {
                    return Err(anyhow!(
                        "subtree child {j} claims leaf shard {leaf}, expected {}",
                        a + top * j
                    ));
                }
                if init_params.is_none() {
                    init_params = Some(params);
                }
            }
            t => return Err(anyhow!("unexpected {t:?} from subtree child {j} during startup")),
        }
    }
    let init_params =
        init_params.ok_or_else(|| anyhow!("aggregator subtree produced no READY (children == 0?)"))?;
    let manifest = init_params.manifest.clone();
    wire::encode_ready(&mut out, a, &init_params);
    up_sink
        .send(&out)
        .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;

    // Lane recycling across rounds, mirroring the coordinator's fan-in.
    let mut free: Vec<RoundLane> = Vec::new();
    loop {
        match up_source.recv(&mut inbuf) {
            Ok(true) => {}
            Ok(false) => break, // coordinator hung up: clean teardown
            Err(e) => return Err(anyhow!("coordinator receive failed: {e:#}")),
        }
        match wire::cmd_tag(&inbuf)? {
            CmdTag::Init => return Err(anyhow!("unexpected second INIT handshake")),
            CmdTag::Round => {
                let slots = wire::decode_round(&inbuf)?;
                // Fan the slot set out by leaf ownership. EVERY child
                // gets a sub-ROUND, empty included: leaf shards count
                // ROUND commands for their round seed, so all must see
                // all rounds.
                let mut per_child: Vec<Vec<(usize, usize)>> = vec![Vec::new(); children];
                for &(slot, ci) in &slots {
                    per_child[(ci / top) % children].push((slot, ci));
                }
                for (j, sub) in per_child.into_iter().enumerate() {
                    wire::encode_round(&mut out, &sub);
                    kids[j]
                        .0
                        .send(&out)
                        .map_err(|e| anyhow!("subtree child {j}: {e:#}"))?;
                }
                // Collect each child's decoded lanes and reduce them
                // through the shared slot-ordered fan-in before the
                // single upstream ROUND_DONE.
                let mut tagged: Vec<(usize, RoundLane)> = Vec::with_capacity(slots.len());
                for j in 0..children {
                    match recv_child(&mut kids[j].1, &mut buf, j)? {
                        MsgTag::RoundDone => {
                            let (leaf, lanes) =
                                wire::decode_round_done_into(&buf, &manifest, &mut free)?;
                            if leaf != a + top * j {
                                return Err(anyhow!(
                                    "subtree child {j} answered as leaf shard {leaf}, \
                                     expected {}",
                                    a + top * j
                                ));
                            }
                            tagged.extend(lanes);
                        }
                        t => {
                            return Err(anyhow!(
                                "unexpected {t:?} from subtree child {j} during the round"
                            ))
                        }
                    }
                }
                let tagged = scheduler::fan_in(tagged);
                wire::encode_round_done(&mut out, a, &tagged)?;
                up_sink
                    .send(&out)
                    .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;
                free.extend(tagged.into_iter().map(|(_, lane)| lane));
            }
            CmdTag::Apply => {
                if inbuf.len() <= APPLY_EVAL_OFFSET {
                    return Err(anyhow!("malformed APPLY relay frame"));
                }
                // Relay the APPLY bytes verbatim — the broadcast stays
                // the coordinator's exact bitstream — except the eval
                // flag: only child 0 (whose leaf set contains the
                // globally-lowest local client) may evaluate, and only
                // when the coordinator asked this aggregator to.
                let eval = inbuf[APPLY_EVAL_OFFSET] != 0;
                for j in 0..children {
                    inbuf[APPLY_EVAL_OFFSET] = u8::from(eval && j == 0);
                    kids[j]
                        .0
                        .send(&inbuf)
                        .map_err(|e| anyhow!("subtree child {j}: {e:#}"))?;
                }
                if eval {
                    match recv_child(&mut kids[0].1, &mut buf, 0)? {
                        // EVAL carries no shard field — relay verbatim.
                        MsgTag::Eval => up_sink
                            .send(&buf)
                            .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?,
                        t => {
                            return Err(anyhow!(
                                "unexpected {t:?} from subtree child 0 awaiting eval"
                            ))
                        }
                    }
                }
            }
            CmdTag::State => {
                let cmd = wire::decode_state_cmd(&inbuf, &manifest)?;
                if let Some(inst) = &cmd.install {
                    // Membership is static under a tree (the
                    // coordinator rejects the combination up front);
                    // installs only ever re-target the same topology.
                    if inst.shards != top {
                        return Err(anyhow!(
                            "tree aggregation does not support membership resizing \
                             (install under {} top-level shards, subtree built for {top})",
                            inst.shards
                        ));
                    }
                    if inst.shard != a {
                        return Err(anyhow!(
                            "state install re-assigns aggregator {a} to {}",
                            inst.shard
                        ));
                    }
                    for j in 0..children {
                        let owned: Vec<ClientState> = inst
                            .clients
                            .iter()
                            .filter(|c| (c.id / top) % children == j)
                            .cloned()
                            .collect();
                        let sub = StateCmd {
                            collect: cmd.collect,
                            install: Some(StateInstall {
                                shard: a + top * j,
                                shards: leaves,
                                rounds_done: inst.rounds_done,
                                params: inst.params.clone(),
                                clients: owned,
                            }),
                        };
                        wire::encode_state_cmd(&mut out, &sub);
                        kids[j]
                            .0
                            .send(&out)
                            .map_err(|e| anyhow!("subtree child {j}: {e:#}"))?;
                    }
                } else {
                    for j in 0..children {
                        wire::encode_state_cmd(
                            &mut out,
                            &StateCmd {
                                collect: cmd.collect,
                                install: None,
                            },
                        );
                        kids[j]
                            .0
                            .send(&out)
                            .map_err(|e| anyhow!("subtree child {j}: {e:#}"))?;
                    }
                }
                if cmd.collect {
                    let mut all: Vec<ClientState> = Vec::new();
                    for j in 0..children {
                        match recv_child(&mut kids[j].1, &mut buf, j)? {
                            MsgTag::State => {
                                let (leaf, clients) = wire::decode_state_msg(&buf)?;
                                if leaf != a + top * j {
                                    return Err(anyhow!(
                                        "subtree child {j} answered as leaf shard {leaf}, \
                                         expected {}",
                                        a + top * j
                                    ));
                                }
                                all.extend(clients);
                            }
                            t => {
                                return Err(anyhow!(
                                    "unexpected {t:?} from subtree child {j} during state \
                                     collect"
                                ))
                            }
                        }
                    }
                    all.sort_by_key(|c| c.id);
                    wire::encode_state_msg(&mut out, a, &all);
                    up_sink
                        .send(&out)
                        .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;
                }
            }
            CmdTag::Heartbeat => {
                // The aggregator IS the shard upstream probes — echo
                // its own liveness directly; children carry no pending
                // probe (their liveness surfaces as relay errors).
                let nonce = wire::decode_heartbeat_cmd(&inbuf)?;
                wire::encode_heartbeat_msg(&mut out, a, nonce);
                up_sink
                    .send(&out)
                    .map_err(|e| anyhow!("coordinator disconnected: {e:#}"))?;
            }
            CmdTag::Stop => break,
        }
    }
    // Wind the subtree down: STOP every child, drop the pipes, join.
    for (k_sink, _) in kids.iter_mut() {
        wire::encode_stop(&mut out);
        let _ = k_sink.send(&out);
    }
    drop(kids);
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Posts a `ConnDown` for its shard when the worker thread unwinds —
/// the mpsc analogue of a wire reader noticing its connection die.
/// Installed only for supervised runs: unsupervised mpsc death keeps
/// its legacy shape (a silent exit simply closes the channel).
struct ExitGuard {
    tx: mpsc::Sender<ShardMsg>,
    conn: u64,
    shard: usize,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(ShardMsg::ConnDown {
            conn: self.conn,
            shard: self.shard,
            msg: "worker thread exited".into(),
        });
    }
}

/// One shard's mpsc-mode thread body: build the requested compute,
/// then serve round commands until `Stop`. `conn` is the admission's
/// connection generation; `guard` (supervised runs) arms an
/// [`ExitGuard`] so even a silent death surfaces as `ConnDown`.
#[allow(clippy::too_many_arguments)]
fn shard_thread_mpsc(
    cfg: ExperimentConfig,
    compute: ComputeSpec,
    shard: usize,
    shards: usize,
    conn: u64,
    guard: bool,
    chaos: Option<ChaosDeath>,
    obs: Obs,
    cmd_rx: mpsc::Receiver<ShardCmd>,
    msg_tx: mpsc::Sender<ShardMsg>,
) {
    let _guard = guard.then(|| ExitGuard {
        tx: msg_tx.clone(),
        conn,
        shard,
    });
    let run = || -> Result<()> {
        match &compute {
            ComputeSpec::Real => {
                let rt = Runtime::cpu()?;
                let mr = ModelRuntime::open(&rt, &cfg.artifacts_root, &cfg.variant)?;
                let mut body = RealShard::build(&mr, &cfg, shard, shards, obs)?;
                shard_loop_mpsc(&mut body, shard, chaos, &cmd_rx, &msg_tx)
            }
            ComputeSpec::Synthetic { manifest } => {
                let mut body = SynthShard::new(manifest.clone(), &cfg, shards, obs);
                shard_loop_mpsc(&mut body, shard, chaos, &cmd_rx, &msg_tx)
            }
        }
    };
    if let Err(e) = run() {
        let _ = msg_tx.send(ShardMsg::Failed {
            shard,
            msg: format!("{e:#}"),
        });
    }
}

// ---------------------------------------------------------------------------
// Multi-process deployment
// ---------------------------------------------------------------------------

/// Coordinate an experiment over shard workers joining through
/// `listener` (the multi-process server side). Accepts
/// `resolved_shards(&cfg)` connections — polling `liveness` while
/// waiting, so a dead worker fails the join fast — then drives the full
/// wire protocol and returns the [`RunLog`] (with measured
/// [`RunLog::wire`] traffic). Shard identity is assigned by the INIT
/// handshake, so join order does not matter.
pub fn serve(
    cfg: ExperimentConfig,
    listener: &TcpListener,
    compute: ComputeSpec,
    liveness: impl FnMut() -> Result<()>,
    on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    serve_session(
        cfg,
        listener,
        compute,
        ElasticPlan::default(),
        None,
        liveness,
        on_event,
    )
}

/// [`serve`] with full session control: an optional resume state (the
/// coordinator rehydrates the joined workers from the snapshot before
/// the first round — the multi-process leg of `fsfl run --resume`) and
/// a scripted [`ElasticPlan`]. Membership events are satisfied
/// **directly from the listener**: a replacement or a grown shard slot
/// admits the next externally-launched worker that connects (an
/// autoscaler just starts more `fsfl shard-worker` processes — workers
/// that connect before the boundary wait in the accept backlog).
pub fn serve_session(
    cfg: ExperimentConfig,
    listener: &TcpListener,
    compute: ComputeSpec,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    liveness: impl FnMut() -> Result<()>,
    on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    serve_session_observed(cfg, listener, compute, plan, resume, None, liveness, on_event)
}

/// [`serve_session`] with an attached telemetry handle: the serving
/// coordinator's frame endpoints, round lifecycle and supervisor
/// incidents all land in the trace/registry (`fsfl serve
/// --metrics-addr` scrapes the registry live).
#[allow(clippy::too_many_arguments)]
pub fn serve_session_observed(
    cfg: ExperimentConfig,
    listener: &TcpListener,
    compute: ComputeSpec,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    obs: Obs,
    liveness: impl FnMut() -> Result<()>,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    let shards = session_shards(&cfg, resume.as_ref());
    let result = (|| {
        check_wire_cfg(&cfg, &compute)?;
        let mut session = SessionCtx::build(&cfg, &compute, plan, resume)?;
        session.obs = obs;
        let (msg_tx, msg_rx) = mpsc::channel::<ShardMsg>();
        let accept = WireMode::Accept {
            listener: listener
                .try_clone()
                .map_err(|e| anyhow!("cloning the shard listener for admission: {e}"))?,
            liveness: Box::new(liveness),
        };
        let mut admit =
            WireAdmit::new(&cfg, &compute, msg_tx, Some(accept), session.clock.clone());
        admit.obs = session.obs.clone();
        let mut txs: Vec<ShardTx> = Vec::with_capacity(shards);
        let mut active: Vec<u64> = Vec::with_capacity(shards);
        // Initial joins go through the same listener-admission path as
        // mid-run membership events, so the liveness poll guards both.
        for shard in 0..shards {
            let (conn, tx) = admit.admit(shard, shards)?;
            active.push(conn);
            txs.push(tx);
        }
        // With no membership plan no further admission happens
        // (externally-joined workers); keep disconnect detection alive.
        // Elastic runs keep the fan-in sender for later admissions and
        // seal inside the control loop once the plan is exhausted;
        // supervised runs keep it so a respawn can re-admit from the
        // listener.
        if session.plan.is_empty() && !cfg.policy.supervised() {
            admit.seal();
        }
        let result = coordinate(
            &cfg,
            shards,
            &mut txs,
            &mut active,
            &mut admit,
            &msg_rx,
            &mut session,
            &mut on_event,
        );
        teardown_wire(result, txs, &mut admit)
    })();
    match &result {
        Ok(log) => on_event(&Event::Finished(log.clone())),
        Err(e) => on_event(&Event::Failed(format!("{e:#}"))),
    }
    result
}

/// Join a coordinator as one shard worker (the multi-process worker
/// side; `fsfl shard-worker --connect HOST:PORT` calls this). Connects
/// with bounded retry + exponential backoff — a worker racing the
/// coordinator's bind keeps trying instead of dying at startup — then
/// receives the INIT handshake (experiment config + compute spec +
/// shard assignment), serves rounds until STOP, then returns.
pub fn join_shard(addr: &str) -> Result<()> {
    let mut backoff = Backoff::new(
        Duration::from_millis(50),
        Duration::from_secs(2),
        0x5AFE_C0DE_F157_F00D,
    );
    let t = TcpTransport::connect_retry(addr, 10, &mut backoff, &MonotonicClock::new())?;
    serve_shard_transport(Box::new(t))
}

/// Join a coordinator as one **mid-tier aggregator** owning `children`
/// leaf shards (the multi-process tree side; `fsfl aggregator --connect
/// HOST:PORT --children K` calls this). Connects with the same bounded
/// retry + backoff as [`join_shard`], receives the ordinary shard INIT
/// under its top-level slot, spawns its subtree in-process, and serves
/// the aggregation relay (see [`serve_aggregator_transport`]) until
/// STOP.
pub fn join_aggregator(addr: &str, children: usize) -> Result<()> {
    let mut backoff = Backoff::new(
        Duration::from_millis(50),
        Duration::from_secs(2),
        0x5AFE_C0DE_F157_F00D,
    );
    let t = TcpTransport::connect_retry(addr, 10, &mut backoff, &MonotonicClock::new())?;
    serve_aggregator_transport(Box::new(t), children)
}

/// Run a sharded experiment with every shard as a **separate OS
/// process**: binds a localhost listener, spawns one `worker_exe
/// shard-worker --connect <addr>` child per shard, and serves the wire
/// protocol. Children are reaped on success and killed on failure (a
/// child dying early fails the run fast instead of hanging it).
pub fn run_experiment_processes(
    cfg: ExperimentConfig,
    compute: ComputeSpec,
    worker_exe: &Path,
    on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_experiment_processes_session(
        cfg,
        compute,
        worker_exe,
        ElasticPlan::default(),
        None,
        on_event,
    )
}

/// [`run_experiment_processes`] with full session control: an optional
/// resume state (the multi-process leg of `fsfl run --shard-procs
/// --resume`) and a scripted [`ElasticPlan`]. Enough worker processes
/// for the whole plan — the starting set plus one per replacement and
/// per grown slot — are launched up front; the surplus sit connected in
/// the listener's accept backlog until their membership boundary admits
/// them (exactly how an external autoscaler would pre-provision).
pub fn run_experiment_processes_session(
    cfg: ExperimentConfig,
    compute: ComputeSpec,
    worker_exe: &Path,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    run_experiment_processes_session_observed(cfg, compute, worker_exe, plan, resume, None, on_event)
}

/// [`run_experiment_processes_session`] with an attached telemetry
/// handle (coordinator-side only; worker processes stay untraced).
#[allow(clippy::too_many_arguments)]
pub fn run_experiment_processes_session_observed(
    cfg: ExperimentConfig,
    compute: ComputeSpec,
    worker_exe: &Path,
    plan: ElasticPlan,
    resume: Option<SessionState>,
    obs: Obs,
    on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    let shards = session_shards(&cfg, resume.as_ref());
    let workers = shards + plan.admissions(shards);
    // How many workers the plan will deliberately stop (each replace
    // stops one, each shrink stops the difference): the liveness poll
    // below tolerates exactly that many clean (status 0) exits; any
    // clean exit beyond the budget — in particular *any* with no plan —
    // still fails the join fast instead of burning the accept timeout.
    let planned_departures = {
        let mut cur = shards;
        let mut dep = 0usize;
        for (_, ev) in plan.timeline() {
            match ev {
                ElasticEvent::Replace(_) => dep += 1,
                ElasticEvent::Resize(m) => {
                    dep += cur.saturating_sub(m);
                    cur = m;
                }
            }
        }
        dep
    };
    let listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| anyhow!("binding shard listener: {e}"))?;
    let addr = listener
        .local_addr()
        .map_err(|e| anyhow!("listener address: {e}"))?;
    let mut spawned = Vec::with_capacity(workers);
    for shard in 0..workers {
        let child = std::process::Command::new(worker_exe)
            .arg("shard-worker")
            .arg("--connect")
            .arg(addr.to_string())
            .spawn()
            .map_err(|e| {
                anyhow!(
                    "spawning shard worker {shard} via {}: {e}",
                    worker_exe.display()
                )
            })?;
        spawned.push(child);
    }
    let children = std::cell::RefCell::new(spawned);
    let result = serve_session_observed(
        cfg,
        &listener,
        compute,
        plan,
        resume,
        obs,
        || {
            let mut kids = children.borrow_mut();
            let mut clean = 0usize;
            for (i, c) in kids.iter_mut().enumerate() {
                if let Some(status) = c
                    .try_wait()
                    .map_err(|e| anyhow!("polling shard worker {i}: {e}"))?
                {
                    if !status.success() {
                        return Err(anyhow!(
                            "shard worker {i} exited early ({status}) before joining"
                        ));
                    }
                    // A zero exit is a *planned* departure (a shard
                    // stopped by a shrink or replacement winds down
                    // cleanly) — but the plan bounds how many of those
                    // can ever exist; one more means a worker died
                    // cleanly before joining.
                    clean += 1;
                    if clean > planned_departures {
                        return Err(anyhow!(
                            "shard worker {i} exited cleanly before joining \
                             ({clean} clean exits, the plan stops only {planned_departures})"
                        ));
                    }
                }
            }
            Ok(())
        },
        on_event,
    );
    let mut kids = children.into_inner();
    match &result {
        Ok(_) => {
            for c in kids.iter_mut() {
                let _ = c.wait();
            }
        }
        Err(_) => {
            for c in kids.iter_mut() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
    result
}

/// Default per-round progress line used by the CLI and examples.
pub fn print_round(m: &RoundMetrics) {
    println!(
        "round {:>3}  acc {:5.3}  f1 {:5.3}  loss {:7.4}  up {:>10}  down {:>10}  sparsity {:4.1}%  rows-skip {:4.1}%  scaleok {}  t {}ms+{}ms",
        m.round,
        m.accuracy,
        m.f1,
        m.test_loss,
        crate::metrics::fmt_bytes(m.up_bytes),
        crate::metrics::fmt_bytes(m.down_bytes),
        m.update_sparsity * 100.0,
        m.rows_skipped * 100.0,
        m.scale_accepted,
        m.train_ms,
        m.scale_ms,
    );
}
