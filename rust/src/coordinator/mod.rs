//! Leader/worker coordination layer.
//!
//! XLA executables are thread-affine (the `xla` crate's PJRT handles are
//! not `Send`), so the compute plane runs on one dedicated OS thread while
//! the control plane — progress streaming, CSV sinks, the CLI — consumes
//! events from an mpsc channel. [`run_experiment_threaded`] spawns the
//! compute thread and streams [`RoundMetrics`]; this is the launcher used
//! by the `fsfl` binary and the examples.
//!
//! Within a round the compute thread additionally fans the **codec
//! plane** (per-client encode, server-side decode) out across the
//! experiment's [`crate::exec::WorkerPool`] — see `fl/mod.rs` for the
//! stage diagram. The in-process wire protocol is still the *paper's*
//! protocol: clients emit DeepCABAC bitstreams, the server decodes
//! exactly those bytes (`RoundLane::finish_round`), and byte accounting
//! happens on the encoded streams — nothing is short-circuited.

use std::sync::mpsc;

use anyhow::Result;

use crate::fl::{Experiment, ExperimentConfig};
use crate::metrics::{RoundMetrics, RunLog};
use crate::runtime::Runtime;

/// Events streamed from the compute thread to observers.
#[derive(Debug)]
pub enum Event {
    RoundDone(RoundMetrics),
    Finished(RunLog),
    Failed(String),
}

/// Run an experiment on a dedicated compute thread, streaming per-round
/// events to `on_event` on the calling thread. Returns the final
/// [`RunLog`].
pub fn run_experiment_threaded(
    cfg: ExperimentConfig,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    let (tx, rx) = mpsc::channel::<Event>();
    let handle = std::thread::spawn(move || {
        let run = || -> Result<RunLog> {
            let rt = Runtime::cpu()?;
            let mut exp = Experiment::build(&rt, cfg)?;
            let tx2 = tx.clone();
            let log = exp.run_with(move |m| {
                let _ = tx2.send(Event::RoundDone(m.clone()));
            })?;
            Ok(log)
        };
        match run() {
            Ok(log) => {
                let _ = tx.send(Event::Finished(log));
            }
            Err(e) => {
                let _ = tx.send(Event::Failed(format!("{e:#}")));
            }
        }
    });

    let mut result: Option<RunLog> = None;
    for ev in rx {
        on_event(&ev);
        match ev {
            Event::Finished(log) => {
                result = Some(log);
                break;
            }
            Event::Failed(msg) => {
                let _ = handle.join();
                return Err(anyhow::anyhow!(msg));
            }
            Event::RoundDone(_) => {}
        }
    }
    handle
        .join()
        .map_err(|_| anyhow::anyhow!("compute thread panicked"))?;
    result.ok_or_else(|| anyhow::anyhow!("experiment ended without result"))
}

/// Synchronous convenience wrapper (shares one [`Runtime`] across calls —
/// used by harnesses that sweep many configs).
pub fn run_experiment(rt: &Runtime, cfg: ExperimentConfig) -> Result<RunLog> {
    let mut exp = Experiment::build(rt, cfg)?;
    exp.run()
}

/// Default per-round progress line used by the CLI and examples.
pub fn print_round(m: &RoundMetrics) {
    println!(
        "round {:>3}  acc {:5.3}  f1 {:5.3}  loss {:7.4}  up {:>10}  down {:>10}  sparsity {:4.1}%  rows-skip {:4.1}%  scaleok {}  t {}ms+{}ms",
        m.round,
        m.accuracy,
        m.f1,
        m.test_loss,
        crate::metrics::fmt_bytes(m.up_bytes),
        crate::metrics::fmt_bytes(m.down_bytes),
        m.update_sparsity * 100.0,
        m.rows_skipped * 100.0,
        m.scale_accepted,
        m.train_ms,
        m.scale_ms,
    );
}
