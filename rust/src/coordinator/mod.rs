//! Leader/worker coordination layer.
//!
//! XLA executables are thread-affine (the `xla` crate's PJRT handles are
//! not `Send`), so compute always runs on dedicated OS threads while the
//! control plane — progress streaming, CSV sinks, the CLI — consumes
//! [`Event`]s from an mpsc channel. Two deployment shapes share that
//! contract:
//!
//! * [`run_experiment_threaded`] — one compute thread drives the whole
//!   [`crate::fl::Experiment`]; the round scheduler (see
//!   `fl/scheduler.rs`) overlaps its codec plane with compute when
//!   `cfg.pipelined` is set.
//! * [`run_experiment_sharded`] — clients are split round-robin over
//!   `cfg.compute_shards` **shard threads**, each owning its own PJRT
//!   client, client subset and codec worker pool. Shards run the same
//!   scheduler over their slice of each round's participants and stream
//!   their finished [`RoundLane`]s into the coordinator over one mpsc
//!   fan-in channel. The coordinator performs the **ordered reduction**
//!   (lanes sorted by round slot — exactly the single-thread aggregation
//!   order), applies FedAvg, and hands the broadcast delta back to every
//!   shard; shard 0 evaluates the central model on its synced replica.
//!
//! Both shapes speak the *paper's* wire protocol: clients emit DeepCABAC
//! bitstreams, the server decodes exactly those bytes
//! (`RoundLane::finish_round`), and byte accounting happens on the
//! encoded streams — nothing is short-circuited. Determinism invariant:
//! for a fixed config, bitstreams and `RunLog` metrics are byte-identical
//! across shard counts, schedule modes and pool widths (see
//! `ARCHITECTURE.md` and `tests/integration_parallel.rs`).

use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::exec::WorkerPool;
use crate::fl::scheduler::{self, ScheduleMode};
use crate::fl::{
    build_setup, evaluate_params, EvalReport, Experiment, ExperimentCompute, ExperimentConfig,
    RoundLane, Server,
};
use crate::metrics::{RoundMetrics, RunLog, ScaleStats};
use crate::model::params::Delta;
use crate::model::ParamSet;
use crate::runtime::{ModelRuntime, Runtime};

/// Events streamed from the compute thread(s) to observers.
#[derive(Debug)]
pub enum Event {
    /// One round finished; carries its metrics.
    RoundDone(RoundMetrics),
    /// The experiment completed with this log.
    Finished(RunLog),
    /// The experiment failed (message is the rendered error chain).
    Failed(String),
}

/// The compute-shard count a config actually resolves to (never more
/// shards than clients, never less than one).
pub fn resolved_shards(cfg: &ExperimentConfig) -> usize {
    cfg.compute_shards.min(cfg.clients).max(1)
}

/// Run an experiment on dedicated compute thread(s), streaming per-round
/// events to `on_event` on the calling thread. Returns the final
/// [`RunLog`]. Dispatches to [`run_experiment_sharded`] when the config
/// asks for more than one compute shard.
pub fn run_experiment_threaded(
    cfg: ExperimentConfig,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    if resolved_shards(&cfg) > 1 {
        return run_experiment_sharded(cfg, on_event);
    }
    run_single_thread(cfg, &mut on_event)
}

/// The single-compute-thread launcher body.
fn run_single_thread(cfg: ExperimentConfig, on_event: &mut impl FnMut(&Event)) -> Result<RunLog> {
    let (tx, rx) = mpsc::channel::<Event>();
    let handle = std::thread::spawn(move || {
        let run = || -> Result<RunLog> {
            let rt = Runtime::cpu()?;
            let mut exp = Experiment::build(&rt, cfg)?;
            let tx2 = tx.clone();
            let log = exp.run_with(move |m| {
                let _ = tx2.send(Event::RoundDone(m.clone()));
            })?;
            Ok(log)
        };
        match run() {
            Ok(log) => {
                let _ = tx.send(Event::Finished(log));
            }
            Err(e) => {
                let _ = tx.send(Event::Failed(format!("{e:#}")));
            }
        }
    });

    let mut result: Option<RunLog> = None;
    for ev in rx {
        on_event(&ev);
        match ev {
            Event::Finished(log) => {
                result = Some(log);
                break;
            }
            Event::Failed(msg) => {
                let _ = handle.join();
                return Err(anyhow::anyhow!(msg));
            }
            Event::RoundDone(_) => {}
        }
    }
    handle
        .join()
        .map_err(|_| anyhow::anyhow!("compute thread panicked"))?;
    result.ok_or_else(|| anyhow::anyhow!("experiment ended without result"))
}

/// Synchronous convenience wrapper (shares one [`Runtime`] across calls —
/// used by harnesses that sweep many configs). Always single-shard: the
/// caller owns the runtime's thread.
pub fn run_experiment(rt: &Runtime, cfg: ExperimentConfig) -> Result<RunLog> {
    let mut exp = Experiment::build(rt, cfg)?;
    exp.run()
}

// ---------------------------------------------------------------------------
// Sharded deployment
// ---------------------------------------------------------------------------

/// Shard → coordinator messages (all shards share one fan-in channel).
enum ShardMsg {
    /// Shard built its runtime + client subset; carries the initial
    /// model so the coordinator can construct the server without a
    /// runtime of its own.
    Ready { shard: usize, init: ParamSet },
    /// One round's finished lanes, each tagged with its global slot.
    RoundDone {
        shard: usize,
        lanes: Vec<(usize, RoundLane)>,
    },
    /// Central-model evaluation after broadcast (shard 0 only).
    Eval {
        report: EvalReport,
        scale_stats: Vec<ScaleStats>,
    },
    /// Fatal shard error (rendered error chain).
    Failed { shard: usize, msg: String },
}

/// Coordinator → shard commands (one channel per shard).
enum ShardCmd {
    /// Run the round over these `(global slot, client id)` assignments
    /// (possibly empty — the shard still participates in the barrier).
    Round { slots: Vec<(usize, usize)> },
    /// Apply the aggregated broadcast to every local replica, take the
    /// round's lanes back for recycling, and — when `eval` — evaluate
    /// the central model on the synced replica.
    Apply {
        broadcast: Arc<Delta>,
        lanes: Vec<(usize, RoundLane)>,
        eval: bool,
    },
    /// Shut down cleanly.
    Stop,
}

/// Run an experiment with clients sharded over `cfg.compute_shards`
/// compute threads (one PJRT client per shard). Streams the same
/// [`Event`]s as [`run_experiment_threaded`] and returns the final
/// [`RunLog`]; outputs are byte-identical to the single-thread path for
/// any shard count.
pub fn run_experiment_sharded(
    cfg: ExperimentConfig,
    mut on_event: impl FnMut(&Event),
) -> Result<RunLog> {
    let shards = resolved_shards(&cfg);
    if shards <= 1 {
        return run_single_thread(cfg, &mut on_event);
    }

    let (msg_tx, msg_rx) = mpsc::channel::<ShardMsg>();
    let mut cmd_txs: Vec<mpsc::Sender<ShardCmd>> = Vec::with_capacity(shards);
    let mut handles = Vec::with_capacity(shards);
    for shard in 0..shards {
        let (cmd_tx, cmd_rx) = mpsc::channel::<ShardCmd>();
        cmd_txs.push(cmd_tx);
        let cfg2 = cfg.clone();
        let tx = msg_tx.clone();
        handles.push(std::thread::spawn(move || {
            shard_worker(cfg2, shard, shards, cmd_rx, tx)
        }));
    }
    drop(msg_tx);

    let result = coordinate(&cfg, shards, &cmd_txs, &msg_rx, &mut on_event);
    // Shut every shard down (dead shards just return a send error).
    for tx in &cmd_txs {
        let _ = tx.send(ShardCmd::Stop);
    }
    for h in handles {
        let _ = h.join();
    }
    match &result {
        Ok(log) => on_event(&Event::Finished(log.clone())),
        Err(e) => on_event(&Event::Failed(format!("{e:#}"))),
    }
    result
}

/// Turn a dead-shard condition into its parked `Failed` message when one
/// is already queued, otherwise the fallback description.
fn shard_failure(msg_rx: &mpsc::Receiver<ShardMsg>, fallback: &str) -> anyhow::Error {
    while let Ok(m) = msg_rx.try_recv() {
        if let ShardMsg::Failed { shard, msg } = m {
            return anyhow!("shard {shard}: {msg}");
        }
    }
    anyhow!("{fallback}")
}

/// The coordinator's control loop: round fan-out, ordered fan-in
/// reduction, FedAvg, broadcast, metrics.
fn coordinate(
    cfg: &ExperimentConfig,
    shards: usize,
    cmd_txs: &[mpsc::Sender<ShardCmd>],
    msg_rx: &mpsc::Receiver<ShardMsg>,
    on_event: &mut impl FnMut(&Event),
) -> Result<RunLog> {
    // Startup barrier: every shard builds its runtime + clients.
    let mut init: Option<ParamSet> = None;
    let mut ready = 0usize;
    while ready < shards {
        match msg_rx.recv() {
            Ok(ShardMsg::Ready { shard, init: i }) => {
                debug_assert!(shard < shards, "ready from unknown shard {shard}");
                ready += 1;
                if init.is_none() {
                    init = Some(i);
                }
            }
            Ok(ShardMsg::Failed { shard, msg }) => return Err(anyhow!("shard {shard}: {msg}")),
            Ok(_) => return Err(anyhow!("unexpected shard message during startup")),
            Err(_) => return Err(shard_failure(msg_rx, "shards exited during startup")),
        }
    }
    let init = init.expect("startup barrier passed without init");

    let mut server = Server::new(init, cfg.downstream_codec());
    let update_idx = server.params.manifest.update_indices();
    let n = cfg.clients;
    let take = ((cfg.participation * n as f64).round() as usize).clamp(1, n);
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut broadcast = Delta::zeros(server.params.manifest.clone());
    // Recycled Arc for the broadcast fan-out: by the time the next round
    // aggregates, every shard has applied and dropped its clone, so the
    // buffer is uniquely owned again and no model-sized allocation
    // happens in steady state (a slow shard only costs a fallback copy).
    let mut bc_slot: Option<Arc<Delta>> = None;
    let mut log = RunLog::new(cfg.name.clone());

    for t in 0..cfg.rounds {
        // Fan-out: the same deterministic participant selection as the
        // single-thread round, split by shard ownership.
        scheduler::select_participants(cfg.seed, t, n, take, &mut order);
        let mut per_shard: Vec<Vec<(usize, usize)>> = vec![Vec::new(); shards];
        for (slot, &ci) in order.iter().enumerate() {
            per_shard[scheduler::shard_of(ci, shards)].push((slot, ci));
        }
        for (s, slots) in per_shard.into_iter().enumerate() {
            cmd_txs[s]
                .send(ShardCmd::Round { slots })
                .map_err(|_| shard_failure(msg_rx, &format!("shard {s} disconnected")))?;
        }

        // Fan-in: collect every shard's lanes, then reduce in slot order.
        let mut tagged: Vec<(usize, RoundLane)> = Vec::with_capacity(take);
        let mut done = 0usize;
        while done < shards {
            match msg_rx.recv() {
                Ok(ShardMsg::RoundDone { shard, lanes }) => {
                    debug_assert!(shard < shards, "lanes from unknown shard {shard}");
                    done += 1;
                    tagged.extend(lanes);
                }
                Ok(ShardMsg::Failed { shard, msg }) => {
                    return Err(anyhow!("shard {shard}: {msg}"))
                }
                Ok(_) => return Err(anyhow!("unexpected shard message during round {t}")),
                Err(_) => return Err(shard_failure(msg_rx, "shards exited mid-round")),
            }
        }
        let mut tagged = scheduler::fan_in(tagged);
        for (_, lane) in tagged.iter_mut() {
            if let Some(e) = lane.error.take() {
                return Err(e);
            }
        }

        // Ordered reduction: metrics + FedAvg exactly as a single-shard
        // round would compute them.
        let mut m = RoundMetrics {
            round: t,
            ..Default::default()
        };
        scheduler::collect_lane_metrics(&mut m, tagged.iter().map(|(_, l)| l), &update_idx);
        let updates: Vec<&Delta> = tagged.iter().map(|(_, l)| &l.decoded).collect();
        let down_bytes_each = server.aggregate_into(&updates, &mut broadcast);
        m.down_bytes = down_bytes_each * n;

        // Broadcast + lane return; shard 0 evaluates the synced replica.
        let mut bc = bc_slot
            .take()
            .unwrap_or_else(|| Arc::new(Delta::zeros(server.params.manifest.clone())));
        let reused = match Arc::get_mut(&mut bc) {
            Some(d) => {
                d.copy_from(&broadcast);
                true
            }
            None => false,
        };
        if !reused {
            bc = Arc::new(broadcast.clone());
        }
        let mut back: Vec<Vec<(usize, RoundLane)>> = vec![Vec::new(); shards];
        for (slot, lane) in tagged {
            back[scheduler::shard_of(lane.client, shards)].push((slot, lane));
        }
        for (s, lanes) in back.into_iter().enumerate() {
            cmd_txs[s]
                .send(ShardCmd::Apply {
                    broadcast: bc.clone(),
                    lanes,
                    eval: s == 0,
                })
                .map_err(|_| shard_failure(msg_rx, &format!("shard {s} disconnected")))?;
        }
        loop {
            match msg_rx.recv() {
                Ok(ShardMsg::Eval {
                    report,
                    scale_stats,
                }) => {
                    m.accuracy = report.accuracy;
                    m.f1 = report.f1;
                    m.test_loss = report.loss;
                    m.scale_stats = scale_stats;
                    break;
                }
                Ok(ShardMsg::Failed { shard, msg }) => {
                    return Err(anyhow!("shard {shard}: {msg}"))
                }
                Ok(_) => return Err(anyhow!("unexpected shard message awaiting eval")),
                Err(_) => return Err(shard_failure(msg_rx, "shards exited awaiting eval")),
            }
        }

        // Keep our reference for reuse next round (shards drop theirs
        // once they have applied the delta).
        bc_slot = Some(bc);

        on_event(&Event::RoundDone(m.clone()));
        let acc = m.accuracy;
        log.push(m);
        if let Some(target) = cfg.target_accuracy {
            if acc >= target {
                break;
            }
        }
    }
    Ok(log)
}

/// One shard's thread body: build a private runtime + client subset,
/// then serve round commands until `Stop`.
fn shard_worker(
    cfg: ExperimentConfig,
    shard: usize,
    shards: usize,
    cmd_rx: mpsc::Receiver<ShardCmd>,
    msg_tx: mpsc::Sender<ShardMsg>,
) {
    let run = || -> Result<()> {
        let rt = Runtime::cpu()?;
        let mr = ModelRuntime::open(&rt, &cfg.artifacts_root, &cfg.variant)?;
        // Identical deterministic substrate on every shard; only the
        // round-robin-owned clients are instantiated here.
        let setup = build_setup(&mr, &cfg, |ci| scheduler::shard_of(ci, shards) == shard)?;
        let mut clients = setup.clients;
        let train_data = setup.train_data;
        let test_batches = setup.test_batches;
        let manifest = mr.manifest.clone();
        let pcfg = cfg.protocol_config();
        let update_idx = manifest.update_indices();
        let scale_idx = manifest.group_indices(crate::model::Group::Scale);
        // Auto-sized pools split the machine between shards instead of
        // each grabbing full parallelism (N shards × ncpu codec threads
        // would just thrash); explicit widths are per-shard as documented.
        let pool = if cfg.codec_workers == 0 {
            let auto = WorkerPool::new(0).workers();
            WorkerPool::new((auto / shards).max(1))
        } else {
            WorkerPool::new(cfg.codec_workers)
        };
        let mode: ScheduleMode = cfg.schedule_mode();

        msg_tx
            .send(ShardMsg::Ready {
                shard,
                init: setup.init,
            })
            .map_err(|_| anyhow!("coordinator disconnected"))?;

        // Recycled lanes: grown to this shard's per-round watermark.
        let mut free: Vec<RoundLane> = Vec::new();
        let mut lanes: Vec<RoundLane> = Vec::new();
        loop {
            match cmd_rx.recv() {
                Ok(ShardCmd::Round { slots }) => {
                    let order: Vec<usize> = slots.iter().map(|&(_, ci)| ci).collect();
                    while free.len() < order.len() {
                        free.push(RoundLane::new(manifest.clone()));
                    }
                    lanes.clear();
                    let keep = free.len() - order.len();
                    lanes.extend(free.drain(keep..));
                    // The same ComputePlane glue the single-process
                    // Experiment uses, with round-robin local indexing.
                    let mut compute = ExperimentCompute {
                        mr: &mr,
                        clients: &mut clients,
                        shards,
                        train_data: &train_data,
                        cfg: &cfg,
                        pcfg: &pcfg,
                    };
                    scheduler::run_round(
                        mode,
                        &pool,
                        &mut compute,
                        &mut lanes,
                        &order,
                        &pcfg,
                        &update_idx,
                        &scale_idx,
                    )?;
                    let tagged: Vec<(usize, RoundLane)> = slots
                        .iter()
                        .map(|&(slot, _)| slot)
                        .zip(lanes.drain(..))
                        .collect();
                    msg_tx
                        .send(ShardMsg::RoundDone {
                            shard,
                            lanes: tagged,
                        })
                        .map_err(|_| anyhow!("coordinator disconnected"))?;
                }
                Ok(ShardCmd::Apply {
                    broadcast,
                    lanes: returned,
                    eval,
                }) => {
                    for c in clients.iter_mut() {
                        c.apply_broadcast(&broadcast);
                    }
                    free.extend(returned.into_iter().map(|(_, l)| l));
                    if eval {
                        // Post-broadcast, every replica equals the server
                        // model; evaluate on this shard's first client
                        // (global client 0 lives on shard 0).
                        let replica = &clients
                            .first()
                            .ok_or_else(|| anyhow!("eval shard owns no clients"))?
                            .global;
                        let report = evaluate_params(&mr, replica, &test_batches)?;
                        let scale_stats = if pcfg.scaled {
                            clients[0]
                                .scale_values()
                                .into_iter()
                                .map(|(layer, vals)| ScaleStats::from_values(&layer, &vals))
                                .collect()
                        } else {
                            Vec::new()
                        };
                        msg_tx
                            .send(ShardMsg::Eval {
                                report,
                                scale_stats,
                            })
                            .map_err(|_| anyhow!("coordinator disconnected"))?;
                    }
                }
                Ok(ShardCmd::Stop) | Err(_) => break,
            }
        }
        Ok(())
    };
    if let Err(e) = run() {
        let _ = msg_tx.send(ShardMsg::Failed {
            shard,
            msg: format!("{e:#}"),
        });
    }
}

/// Default per-round progress line used by the CLI and examples.
pub fn print_round(m: &RoundMetrics) {
    println!(
        "round {:>3}  acc {:5.3}  f1 {:5.3}  loss {:7.4}  up {:>10}  down {:>10}  sparsity {:4.1}%  rows-skip {:4.1}%  scaleok {}  t {}ms+{}ms",
        m.round,
        m.accuracy,
        m.f1,
        m.test_loss,
        crate::metrics::fmt_bytes(m.up_bytes),
        crate::metrics::fmt_bytes(m.down_bytes),
        m.update_sparsity * 100.0,
        m.rows_skipped * 100.0,
        m.scale_accepted,
        m.train_ms,
        m.scale_ms,
    );
}
