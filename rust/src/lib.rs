//! # FSFL — Filter-Scaled Sparse Federated Learning
//!
//! Production reproduction of *"Adaptive Differential Filters for Fast and
//! Communication-Efficient Federated Learning"* (Becking et al., 2022) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: the federated
//!   coordinator (server/clients, FedAvg-style rounds), the differential
//!   update codec (dynamic sparsification → uniform quantization →
//!   DeepCABAC entropy coding), error accumulation, and the per-filter
//!   scale-factor training loop of Algorithm 1 with linear/CAWR learning
//!   rate schedules.
//! * **L2 (python/compile, build time only)** — jax model zoo + train /
//!   scale-train / eval step functions, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — the Pallas `scaled_matmul` kernel:
//!   the paper's Eq. (4) filter scaling fused into the matmul epilogue.
//!
//! Python never runs on the request path: `make artifacts` lowers
//! everything once, then the rust binary loads `artifacts/*/*.hlo.txt`
//! through the PJRT C API (`xla` crate) and drives the whole FL process.
//!
//! Entry points: [`fl::Experiment`] (programmatic), `fsfl` CLI (launcher),
//! `examples/` (quickstart + scenario drivers). The round execution
//! model — compute plane × codec plane × scheduler, and the determinism
//! invariant every parallel shape upholds — is documented in
//! `ARCHITECTURE.md` at the repository root.

#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
pub mod benchkit;
pub mod cli;
pub mod compression;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod fl;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod session;
pub mod supervise;
pub mod runtime;

pub use anyhow::{anyhow, Result};

/// Crate-wide f32 tolerance used by tests comparing against python refs.
pub const F32_TOL: f32 = 1e-4;
