//! Durable session plane: versioned checkpoint/resume for FL runs.
//!
//! FSFL's convergence depends on state that lives *between* rounds —
//! the Eq. 5 error-accumulation residuals, optimizer moments, the
//! per-client RNG/schedule positions and the server model itself. This
//! module makes that state durable: at a configurable round cadence
//! (see [`crate::fl::SessionConfig`]) the coordinator collects every
//! shard's client state over the `STATE` wire pair, assembles a
//! [`SessionState`] and writes it through [`SessionStore`] as one
//! **versioned, FNV-checksummed snapshot file**.
//!
//! # Snapshot file format
//!
//! A snapshot is exactly one [`crate::net::frame`] frame on disk —
//! the same length-prefix + FNV-1a-checksum discipline the shard wire
//! protocol uses, so truncation (a crash mid-write) and bit rot are
//! both detected at read time with a descriptive error, never a
//! partially-applied state:
//!
//! ```text
//! FSNT frame header (magic, payload length, FNV-1a of the payload)
//! payload:
//!   0x51 snapshot tag | u8 SNAPSHOT_VERSION
//!   bool synthetic plane?
//!   bytes experiment config        (net::wire config codec, exact)
//!   u64  next_round                (rounds already completed)
//!   str  manifest.tsv              (the model contract)
//!   bytes server params            (FSTB tensor bundle, model::io)
//!   RunLog rounds + per-client ClientStates
//! ```
//!
//! Writes are atomic: the frame goes to a dot-tmp file, is fsynced and
//! then renamed into place, so a kill at any instant leaves either the
//! previous snapshot set or a complete new snapshot — [`SessionStore::latest`]
//! skips unreadable files and falls back to the newest valid one.
//!
//! # Resume determinism invariant
//!
//! Resuming a killed run from its last snapshot produces **byte
//! identical** remaining bitstreams and a byte-identical final
//! [`RunLog`] compared to the uninterrupted run, for every transport
//! and schedule shape — pinned on the `fl::synth` plane by
//! `tests/integration_session.rs` (same invariant the transport
//! conformance grid pins for deployment shapes).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

pub mod pager;
pub use pager::ClientPager;

use crate::fl::{ClientState, ExperimentConfig};
use crate::metrics::{RoundMetrics, ScaleStats};
use crate::model::params::ParamSet;
use crate::model::{read_bundle_from, write_bundle_to, BundleTensor, Manifest};
use crate::net::frame;
use crate::net::wire::{self, Rd};

/// Snapshot layout revision; bumped on any incompatible change. A
/// mismatch fails [`decode_snapshot`] with a descriptive error instead
/// of a misparse.
/// v2: the snapshot carries the live shard assignment (`shards`), so
/// `--resume` rebuilds the post-resize worker set after an elastic
/// resize, and the embedded config codec gained the session `retain`
/// knob.
/// v3: the embedded config codec grew the round-supervision policy
/// block (wire protocol v4), changing the snapshot layout.
/// v4: the embedded config codec grew the hierarchy block — tree
/// fan-out + cold-state paging budget (wire protocol v5), changing the
/// snapshot layout.
pub const SNAPSHOT_VERSION: u8 = 4;

/// First payload byte of every snapshot (distinct from all wire tags,
/// so a misrouted file is caught immediately).
const SNAP_TAG: u8 = 0x51;

/// Snapshot filename prefix (`snap-<next_round>.fss`).
const SNAP_PREFIX: &str = "snap-";
/// Snapshot filename extension.
const SNAP_EXT: &str = ".fss";

/// Default snapshot retention for [`SessionStore::write`]: the new one
/// plus one predecessor, so a crash mid-write always leaves a valid
/// fallback (see [`SessionStore::with_retain`] for the GC knob).
const KEEP: usize = crate::fl::SessionConfig::DEFAULT_RETAIN;

/// The complete durable state of an experiment at a round boundary.
pub struct SessionState {
    /// The exact experiment configuration of the original run (resume
    /// re-runs it verbatim; floats travel as bit patterns).
    pub cfg: ExperimentConfig,
    /// Whether the run executed on the synthetic compute plane
    /// (`fsfl run --synth` / the CI session job) instead of real PJRT
    /// clients.
    pub synthetic: bool,
    /// Rounds already completed; resume continues at this round index.
    pub next_round: usize,
    /// The live shard assignment when the snapshot was taken. After an
    /// elastic resize this differs from the config's `compute_shards`;
    /// resume spawns exactly this many workers so the post-resize
    /// membership is rebuilt as checkpointed.
    pub shards: usize,
    /// The model contract, as `manifest.tsv` text.
    pub manifest_tsv: String,
    /// Server parameters as a named tensor bundle (validated against
    /// the manifest on resume).
    pub params: Vec<BundleTensor>,
    /// The accumulated per-round log of the completed rounds.
    pub rounds: Vec<RoundMetrics>,
    /// Every client's round-boundary state (empty on the synthetic
    /// plane, which carries no per-client state).
    pub clients: Vec<ClientState>,
}

impl SessionState {
    /// Shape the snapshot's server parameters against `manifest`,
    /// validating tensor names and sizes (descriptive error, nothing
    /// half-built).
    pub fn params_for(&self, manifest: &std::sync::Arc<Manifest>) -> Result<ParamSet> {
        if self.params.len() != manifest.tensors.len() {
            return Err(anyhow!(
                "snapshot carries {} parameter tensors, manifest wants {}",
                self.params.len(),
                manifest.tensors.len()
            ));
        }
        let mut tensors = Vec::with_capacity(self.params.len());
        for (bt, spec) in self.params.iter().zip(&manifest.tensors) {
            if bt.name != spec.name {
                return Err(anyhow!(
                    "snapshot tensor order mismatch: {} != {}",
                    bt.name,
                    spec.name
                ));
            }
            if bt.data.len() != spec.numel() {
                return Err(anyhow!(
                    "{}: snapshot has {} values, manifest wants {}",
                    bt.name,
                    bt.data.len(),
                    spec.numel()
                ));
            }
            tensors.push(bt.data.clone());
        }
        ParamSet::new(manifest.clone(), tensors)
    }

    /// Build the params bundle from a live [`ParamSet`].
    pub fn bundle_params(params: &ParamSet) -> Vec<BundleTensor> {
        params
            .manifest
            .tensors
            .iter()
            .zip(&params.tensors)
            .map(|(spec, data)| BundleTensor {
                name: spec.name.clone(),
                shape: spec.shape.clone(),
                data: data.clone(),
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// snapshot codec
// ---------------------------------------------------------------------------

fn put_round_metrics(buf: &mut Vec<u8>, m: &RoundMetrics) -> Result<()> {
    wire::put_usize(buf, m.round);
    wire::put_usize(buf, m.up_bytes);
    wire::put_usize(buf, m.down_bytes);
    wire::put_f64(buf, m.accuracy);
    wire::put_f64(buf, m.f1);
    wire::put_f64(buf, m.test_loss);
    wire::put_f64(buf, m.update_sparsity);
    wire::put_usize(buf, m.client_sparsity.len());
    for &s in &m.client_sparsity {
        wire::put_f64(buf, s);
    }
    wire::put_f64(buf, m.rows_skipped);
    wire::put_usize(buf, m.scale_accepted);
    wire::put_u64(
        buf,
        u64::try_from(m.train_ms).map_err(|_| anyhow!("train_ms overflows the snapshot"))?,
    );
    wire::put_u64(
        buf,
        u64::try_from(m.scale_ms).map_err(|_| anyhow!("scale_ms overflows the snapshot"))?,
    );
    wire::put_usize(buf, m.scale_stats.len());
    for s in &m.scale_stats {
        wire::put_str(buf, &s.layer);
        wire::put_f32(buf, s.min);
        wire::put_f32(buf, s.q25);
        wire::put_f32(buf, s.median);
        wire::put_f32(buf, s.q75);
        wire::put_f32(buf, s.max);
        wire::put_f32(buf, s.mean);
        wire::put_f32(buf, s.suppressed);
    }
    Ok(())
}

fn read_round_metrics(rd: &mut Rd) -> Result<RoundMetrics> {
    let round = rd.usize_()?;
    let up_bytes = rd.usize_()?;
    let down_bytes = rd.usize_()?;
    let accuracy = rd.f64()?;
    let f1 = rd.f64()?;
    let test_loss = rd.f64()?;
    let update_sparsity = rd.f64()?;
    let n = rd.usize_()?;
    if n > rd.remaining() / 8 {
        return Err(anyhow!(
            "implausible client-sparsity count {n} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut client_sparsity = Vec::with_capacity(n);
    for _ in 0..n {
        client_sparsity.push(rd.f64()?);
    }
    let rows_skipped = rd.f64()?;
    let scale_accepted = rd.usize_()?;
    let train_ms = rd.u64()? as u128;
    let scale_ms = rd.u64()? as u128;
    let n = rd.usize_()?;
    if n > rd.remaining() {
        return Err(anyhow!(
            "implausible scale-stats count {n} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut scale_stats = Vec::with_capacity(n.min(1 << 10));
    for _ in 0..n {
        scale_stats.push(ScaleStats {
            layer: rd.str_()?,
            min: rd.f32()?,
            q25: rd.f32()?,
            median: rd.f32()?,
            q75: rd.f32()?,
            max: rd.f32()?,
            mean: rd.f32()?,
            suppressed: rd.f32()?,
        });
    }
    Ok(RoundMetrics {
        round,
        up_bytes,
        down_bytes,
        accuracy,
        f1,
        test_loss,
        update_sparsity,
        client_sparsity,
        rows_skipped,
        scale_accepted,
        train_ms,
        scale_ms,
        scale_stats,
    })
}

/// Serialize a [`SessionState`] into `buf` (cleared first). Exact
/// round-trip through [`decode_snapshot`]: floats travel as bit
/// patterns, so resumed state equals checkpointed state bit for bit.
pub fn encode_snapshot(buf: &mut Vec<u8>, st: &SessionState) -> Result<()> {
    buf.clear();
    buf.push(SNAP_TAG);
    buf.push(SNAPSHOT_VERSION);
    wire::put_bool(buf, st.synthetic);
    let mut cfg_bytes = Vec::new();
    wire::encode_config(&mut cfg_bytes, &st.cfg);
    wire::put_bytes(buf, &cfg_bytes);
    wire::put_usize(buf, st.next_round);
    wire::put_usize(buf, st.shards);
    wire::put_str(buf, &st.manifest_tsv);
    let mut bundle = Vec::new();
    write_bundle_to(&mut bundle, &st.params)?;
    wire::put_bytes(buf, &bundle);
    wire::put_usize(buf, st.rounds.len());
    for m in &st.rounds {
        put_round_metrics(buf, m)?;
    }
    wire::put_usize(buf, st.clients.len());
    for c in &st.clients {
        wire::put_client_state(buf, c);
    }
    Ok(())
}

/// The shared header prefix of a snapshot payload — everything before
/// the round-metrics block. Read by ONE function
/// ([`read_snapshot_header`]) for both [`decode_snapshot`] and the
/// metadata-only inspector, so the two walks can never skew when the
/// layout changes.
struct SnapshotHeader<'a> {
    /// Layout revision the file carries (already validated).
    version: u8,
    /// Whether the run executed on the synthetic compute plane.
    synthetic: bool,
    /// Raw config block (net/wire config codec), not yet decoded.
    cfg: &'a [u8],
    /// Rounds already completed.
    next_round: usize,
    /// The live shard assignment when the snapshot was taken.
    shards: usize,
    /// The model contract, as `manifest.tsv` text.
    manifest_tsv: String,
    /// Raw server-params FSTB bundle, not yet decoded.
    bundle: &'a [u8],
}

/// Read (and validate tag/version of) a snapshot payload's header
/// prefix, leaving `rd` positioned at the round-metrics block.
fn read_snapshot_header<'a>(rd: &mut Rd<'a>) -> Result<SnapshotHeader<'a>> {
    let tag = rd.u8()?;
    if tag != SNAP_TAG {
        return Err(anyhow!(
            "not a session snapshot (leading byte {tag:#04x}, want {SNAP_TAG:#04x})"
        ));
    }
    let version = rd.u8()?;
    if version != SNAPSHOT_VERSION {
        return Err(anyhow!(
            "snapshot version mismatch: file is v{version}, this binary reads v{SNAPSHOT_VERSION}"
        ));
    }
    Ok(SnapshotHeader {
        version,
        synthetic: rd.bool_()?,
        cfg: rd.bytes()?,
        next_round: rd.usize_()?,
        shards: rd.usize_()?,
        manifest_tsv: rd.str_()?,
        bundle: rd.bytes()?,
    })
}

/// Inverse of [`encode_snapshot`]. Tag/version mismatches and any
/// structural inconsistency error descriptively; a fresh state is
/// built or nothing is (no partial apply).
pub fn decode_snapshot(payload: &[u8]) -> Result<SessionState> {
    let mut rd = Rd::new(payload);
    let h = read_snapshot_header(&mut rd)?;
    let cfg = wire::decode_config(h.cfg)?;
    let mut bundle_bytes = h.bundle;
    let params = read_bundle_from(&mut bundle_bytes).context("snapshot params bundle")?;
    let n = rd.usize_()?;
    if n > rd.remaining() {
        return Err(anyhow!(
            "implausible round count {n} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut rounds = Vec::with_capacity(n.min(1 << 12));
    for _ in 0..n {
        rounds.push(read_round_metrics(&mut rd)?);
    }
    let clients = wire::read_client_states(&mut rd)?;
    rd.done()?;
    Ok(SessionState {
        cfg,
        synthetic: h.synthetic,
        next_round: h.next_round,
        shards: h.shards,
        manifest_tsv: h.manifest_tsv,
        params,
        rounds,
        clients,
    })
}

// ---------------------------------------------------------------------------
// session store
// ---------------------------------------------------------------------------

/// A directory of round-boundary snapshots with atomic writes, pruning
/// and newest-valid fallback.
pub struct SessionStore {
    dir: PathBuf,
    /// How many snapshots [`SessionStore::write`] keeps (≥ 1).
    retain: usize,
}

impl SessionStore {
    /// Open (creating if needed) a session directory with the default
    /// retention ([`crate::fl::SessionConfig::DEFAULT_RETAIN`]).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating session dir {}", dir.display()))?;
        Ok(Self { dir, retain: KEEP })
    }

    /// Set how many snapshots each [`SessionStore::write`] keeps.
    /// Values below 1 are clamped to 1 (the snapshot just written is
    /// never pruned).
    pub fn with_retain(mut self, retain: usize) -> Self {
        self.retain = retain.max(1);
        self
    }

    /// The directory this store writes into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the snapshot taken after `next_round` completed rounds.
    pub fn snapshot_path(&self, next_round: usize) -> PathBuf {
        self.dir
            .join(format!("{SNAP_PREFIX}{next_round:08}{SNAP_EXT}"))
    }

    /// Every `snap-*.fss` file present, as `(next_round, path)` sorted
    /// ascending by round. Files that don't parse as snapshot names are
    /// ignored (they are not ours to manage).
    pub fn snapshots(&self) -> Result<Vec<(usize, PathBuf)>> {
        let mut out = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing session dir {}", self.dir.display()))?;
        for e in entries {
            let e = e?;
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name
                .strip_prefix(SNAP_PREFIX)
                .and_then(|s| s.strip_suffix(SNAP_EXT))
            else {
                continue;
            };
            if let Ok(round) = stem.parse::<usize>() {
                out.push((round, e.path()));
            }
        }
        out.sort_by_key(|&(r, _)| r);
        Ok(out)
    }

    /// Write `st` as an atomic snapshot (tmp file → fsync → rename),
    /// then prune to the newest `retain` snapshots (see
    /// [`SessionStore::with_retain`]). Returns the final path.
    ///
    /// Prune failures are surfaced: a full or read-only disk that keeps
    /// `remove_file` from succeeding would otherwise accumulate
    /// snapshots unnoticed until the volume fills. The snapshot itself
    /// is already durable on disk when the error is returned — the
    /// caller loses nothing but must hear about the failing GC.
    pub fn write(&self, st: &SessionState) -> Result<PathBuf> {
        let mut payload = Vec::new();
        encode_snapshot(&mut payload, st)?;
        let finalp = self.snapshot_path(st.next_round);
        let tmp = self
            .dir
            .join(format!(".{SNAP_PREFIX}{:08}.tmp", st.next_round));
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            frame::write_frame(&mut f, &payload)?;
            f.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &finalp)
            .with_context(|| format!("publishing {}", finalp.display()))?;
        // Prune: keep the newest `retain` so a later torn write always
        // has a valid fallback.
        let all = self.snapshots()?;
        if all.len() > self.retain {
            for (_, p) in &all[..all.len() - self.retain] {
                std::fs::remove_file(p).with_context(|| {
                    format!(
                        "pruning old snapshot {} (snapshots are accumulating)",
                        p.display()
                    )
                })?;
            }
        }
        Ok(finalp)
    }

    /// Load one snapshot file: the frame layer verifies length and
    /// checksum (truncation/bit flips error descriptively), then the
    /// payload decodes into a fresh [`SessionState`].
    pub fn load(path: impl AsRef<Path>) -> Result<SessionState> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading snapshot {}", path.display()))?;
        let mut r = bytes.as_slice();
        let mut payload = Vec::new();
        let got = frame::read_frame(&mut r, &mut payload, frame::MAX_PAYLOAD)
            .with_context(|| format!("snapshot {}", path.display()))?;
        if !got {
            return Err(anyhow!("snapshot {} is empty", path.display()));
        }
        decode_snapshot(&payload).with_context(|| format!("snapshot {}", path.display()))
    }

    /// The newest snapshot that loads cleanly, skipping torn or corrupt
    /// files (the kill-mid-write fallback). `Ok(None)` when the
    /// directory holds no usable snapshot.
    pub fn latest(&self) -> Result<Option<SessionState>> {
        let mut all = self.snapshots()?;
        all.reverse();
        for (_, path) in all {
            match Self::load(&path) {
                Ok(st) => return Ok(Some(st)),
                Err(_) => continue, // torn write; fall back to older
            }
        }
        Ok(None)
    }

    /// Metadata for every snapshot file in the store, newest first —
    /// what `fsfl session inspect DIR` prints. Torn/corrupt files are
    /// reported as [`SnapshotStatus::Torn`] entries instead of failing
    /// the listing, so an operator sees *which* file is damaged.
    pub fn inspect(&self) -> Result<Vec<SnapshotMeta>> {
        let mut all = self.snapshots()?;
        all.reverse();
        Ok(all
            .into_iter()
            .map(|(_, p)| Self::inspect_file(p))
            .collect())
    }

    /// Metadata for one snapshot file without materializing the server
    /// parameters or client states: the frame layer still verifies the
    /// whole-file checksum, but the payload walk *skips over* the
    /// params bundle and client-state slabs instead of decoding them
    /// into tensors, so peak memory stays at one file read (no 4×
    /// `Vec<f32>` expansion). Infallible per file: damage — including a
    /// file pruned away by a live run between listing and read — is
    /// reported in the returned [`SnapshotMeta::status`], never as an
    /// error that would hide the rest of a listing.
    pub fn inspect_file(path: impl Into<PathBuf>) -> SnapshotMeta {
        let path = path.into();
        let file_size = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let status = match Self::inspect_payload_of(&path) {
            Ok(info) => SnapshotStatus::Valid(info),
            Err(e) => SnapshotStatus::Torn(format!("{e:#}")),
        };
        SnapshotMeta {
            path,
            file_size,
            status,
        }
    }

    /// The checksum-verified, metadata-only payload walk behind
    /// [`SessionStore::inspect_file`].
    fn inspect_payload_of(path: &Path) -> Result<SnapshotInfo> {
        let bytes = std::fs::read(path)?;
        let mut r = bytes.as_slice();
        let mut payload = Vec::new();
        if !frame::read_frame(&mut r, &mut payload, frame::MAX_PAYLOAD)? {
            return Err(anyhow!("empty file"));
        }
        let mut rd = Rd::new(&payload);
        // The exact header walk decode_snapshot uses — the config and
        // params blocks come back as raw slices, which the inspector
        // checksums instead of decoding.
        let h = read_snapshot_header(&mut rd)?;
        let params_bytes = h.bundle.len();
        let params_checksum = frame::fnv1a(h.bundle);
        let n = rd.usize_()?;
        if n > rd.remaining() {
            return Err(anyhow!(
                "implausible round count {n} for {} remaining bytes",
                rd.remaining()
            ));
        }
        for _ in 0..n {
            read_round_metrics(&mut rd)?; // small; validates structure
        }
        let clients = wire::skip_client_states(&mut rd)?;
        rd.done()?;
        Ok(SnapshotInfo {
            version: h.version,
            synthetic: h.synthetic,
            next_round: h.next_round,
            shards: h.shards,
            rounds: n,
            clients,
            params_bytes,
            params_checksum,
        })
    }
}

/// Whether a snapshot file parsed cleanly (metadata inside) or is
/// damaged (torn write, bit rot, version mismatch — reason inside).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SnapshotStatus {
    /// The file's frame checksum and payload structure verified.
    Valid(SnapshotInfo),
    /// The file cannot be used; the string is the rendered error chain.
    Torn(String),
}

/// Parsed snapshot metadata (no parameters or client states are
/// materialized to produce this — see [`SessionStore::inspect_file`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapshotInfo {
    /// Snapshot layout revision the file carries.
    pub version: u8,
    /// Whether the run executed on the synthetic compute plane.
    pub synthetic: bool,
    /// Rounds completed when the snapshot was taken.
    pub next_round: usize,
    /// The live shard assignment when the snapshot was taken.
    pub shards: usize,
    /// How many per-round metric records the snapshot carries.
    pub rounds: usize,
    /// How many client states the snapshot carries.
    pub clients: usize,
    /// Size of the embedded server-parameter bundle in bytes.
    pub params_bytes: usize,
    /// FNV-1a checksum of the embedded server-parameter bundle.
    pub params_checksum: u64,
}

/// One snapshot file's inspection record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapshotMeta {
    /// The snapshot file.
    pub path: PathBuf,
    /// On-disk file size in bytes.
    pub file_size: u64,
    /// Valid metadata or the damage report.
    pub status: SnapshotStatus,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TaskKind;
    use crate::fl::{OptSnapshot, Protocol};

    fn sample_state() -> SessionState {
        let m = crate::fl::synth::demo_manifest();
        let mut params = ParamSet::new(
            m.clone(),
            m.tensors.iter().map(|t| vec![0.0; t.numel()]).collect(),
        )
        .unwrap();
        params.tensors[0][7] = -0.125;
        params.tensors[3][10] = 3.25e-5;
        let mut cfg = ExperimentConfig::quick("synth", TaskKind::CifarLike, Protocol::Fsfl);
        cfg.rounds = 9;
        cfg.seed = 1234;
        SessionState {
            cfg,
            synthetic: true,
            next_round: 4,
            shards: 2,
            manifest_tsv: m.to_tsv(),
            params: SessionState::bundle_params(&params),
            rounds: vec![RoundMetrics {
                round: 3,
                up_bytes: 100,
                down_bytes: 200,
                accuracy: 0.5,
                f1: 0.25,
                test_loss: 1.5,
                update_sparsity: 0.9,
                client_sparsity: vec![0.8, 1.0],
                rows_skipped: 0.5,
                scale_accepted: 1,
                train_ms: 12,
                scale_ms: 3,
                scale_stats: vec![ScaleStats {
                    layer: "conv1".into(),
                    min: -1.0,
                    q25: 0.0,
                    median: 0.5,
                    q75: 0.75,
                    max: 1.5,
                    mean: 0.4,
                    suppressed: 0.1,
                }],
            }],
            clients: vec![ClientState {
                id: 1,
                rng: 99,
                sched_global: 7,
                sched_period: 2,
                train_order: vec![3, 1, 2, 0],
                residual: None,
                wopt: OptSnapshot {
                    m: vec![vec![0.5]],
                    v: vec![vec![0.25]],
                    t: 4.0,
                },
                sopt: OptSnapshot {
                    m: vec![],
                    v: vec![],
                    t: 0.0,
                },
            }],
        }
    }

    fn assert_states_eq(a: &SessionState, b: &SessionState) {
        assert_eq!(format!("{:?}", a.cfg), format!("{:?}", b.cfg));
        assert_eq!(a.synthetic, b.synthetic);
        assert_eq!(a.next_round, b.next_round);
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.manifest_tsv, b.manifest_tsv);
        assert_eq!(a.params, b.params);
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.clients, b.clients);
    }

    #[test]
    fn snapshot_round_trips_exactly() {
        let st = sample_state();
        let mut buf = Vec::new();
        encode_snapshot(&mut buf, &st).unwrap();
        let back = decode_snapshot(&buf).unwrap();
        assert_states_eq(&st, &back);
        // params re-shape cleanly against the manifest
        let m = std::sync::Arc::new(Manifest::parse(&st.manifest_tsv).unwrap());
        let p = back.params_for(&m).unwrap();
        assert_eq!(p.tensors[0][7], -0.125);
    }

    #[test]
    fn snapshot_version_and_tag_mismatch_are_descriptive() {
        let st = sample_state();
        let mut buf = Vec::new();
        encode_snapshot(&mut buf, &st).unwrap();
        let mut bad = buf.clone();
        bad[1] = SNAPSHOT_VERSION + 1;
        let err = format!("{}", decode_snapshot(&bad).unwrap_err());
        assert!(err.contains("version"), "undescriptive: {err}");
        let mut bad = buf;
        bad[0] = 0x7F;
        let err = format!("{}", decode_snapshot(&bad).unwrap_err());
        assert!(err.contains("not a session snapshot"), "undescriptive: {err}");
    }

    #[test]
    fn store_write_load_latest_and_prune() {
        let dir = std::env::temp_dir().join(format!("fsfl_session_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SessionStore::open(&dir).unwrap();
        assert!(store.latest().unwrap().is_none(), "empty dir has no snapshot");
        let mut st = sample_state();
        for round in [2usize, 3, 4] {
            st.next_round = round;
            store.write(&st).unwrap();
        }
        // pruned to the newest KEEP
        let names = store.snapshots().unwrap();
        assert_eq!(
            names.iter().map(|(r, _)| *r).collect::<Vec<_>>(),
            vec![3, 4],
            "prune must keep the newest {KEEP}"
        );
        let latest = store.latest().unwrap().expect("snapshot present");
        assert_eq!(latest.next_round, 4);
        // torn newest file → fall back to the previous valid snapshot
        let torn = store.snapshot_path(5);
        let bytes = std::fs::read(store.snapshot_path(4)).unwrap();
        std::fs::write(&torn, &bytes[..bytes.len() / 2]).unwrap();
        let latest = store.latest().unwrap().expect("fallback snapshot");
        assert_eq!(latest.next_round, 4, "must fall back past the torn file");
        // and loading the torn file directly is a descriptive error
        let err = format!("{:#}", SessionStore::load(&torn).unwrap_err());
        assert!(err.contains("mid-frame"), "undescriptive: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_is_configurable_and_prune_failures_surface() {
        let dir = std::env::temp_dir().join(format!("fsfl_session_retain_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // retain 3 keeps three snapshots where the default keeps two
        let store = SessionStore::open(&dir).unwrap().with_retain(3);
        let mut st = sample_state();
        for round in 1..=5usize {
            st.next_round = round;
            store.write(&st).unwrap();
        }
        assert_eq!(
            store
                .snapshots()
                .unwrap()
                .iter()
                .map(|(r, _)| *r)
                .collect::<Vec<_>>(),
            vec![3, 4, 5],
            "retain=3 must keep the newest three"
        );
        // retain < 1 clamps to 1: only the newest survives a write
        let store = SessionStore::open(&dir).unwrap().with_retain(0);
        st.next_round = 6;
        store.write(&st).unwrap();
        assert_eq!(
            store
                .snapshots()
                .unwrap()
                .iter()
                .map(|(r, _)| *r)
                .collect::<Vec<_>>(),
            vec![6]
        );
        // A prune target that cannot be removed (a directory wearing a
        // snapshot name — remove_file fails on it, standing in for a
        // read-only/full disk) must surface, not be swallowed.
        let blocker = store.snapshot_path(1);
        std::fs::create_dir_all(blocker.join("x")).unwrap();
        st.next_round = 7;
        let err = format!("{:#}", store.write(&st).unwrap_err());
        assert!(
            err.contains("pruning old snapshot"),
            "prune failure swallowed: {err}"
        );
        // …and the snapshot itself still landed before the GC error.
        assert!(store.snapshot_path(7).is_file());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn inspect_reports_metadata_without_decoding_params() {
        let dir = std::env::temp_dir().join(format!("fsfl_session_inspect_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SessionStore::open(&dir).unwrap();
        let st = sample_state();
        let path = store.write(&st).unwrap();
        // one torn file alongside the valid one
        let torn = store.snapshot_path(9);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&torn, &bytes[..bytes.len() / 2]).unwrap();

        let metas = store.inspect().unwrap();
        assert_eq!(metas.len(), 2, "both files listed");
        // newest first: the torn snapshot-9 file leads
        assert_eq!(metas[0].path, torn);
        assert_eq!(metas[0].file_size, (bytes.len() / 2) as u64);
        match &metas[0].status {
            SnapshotStatus::Torn(reason) => {
                assert!(reason.contains("mid-frame"), "undescriptive: {reason}")
            }
            SnapshotStatus::Valid(_) => panic!("torn file reported valid"),
        }
        match &metas[1].status {
            SnapshotStatus::Valid(info) => {
                assert_eq!(info.version, SNAPSHOT_VERSION);
                assert!(info.synthetic);
                assert_eq!(info.next_round, 4);
                assert_eq!(info.shards, 2);
                assert_eq!(info.rounds, 1);
                assert_eq!(info.clients, 1);
                assert!(info.params_bytes > 0);
                // the checksum is of the exact embedded bundle bytes
                let mut bundle = Vec::new();
                write_bundle_to(&mut bundle, &st.params).unwrap();
                assert_eq!(info.params_checksum, frame::fnv1a(&bundle));
            }
            SnapshotStatus::Torn(r) => panic!("valid file reported torn: {r}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bit_flips_are_detected_by_the_frame_checksum() {
        let dir = std::env::temp_dir().join(format!("fsfl_session_flip_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SessionStore::open(&dir).unwrap();
        let st = sample_state();
        let path = store.write(&st).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", SessionStore::load(&path).unwrap_err());
        assert!(
            err.contains("checksum") || err.contains("magic") || err.contains("oversized"),
            "undescriptive: {err}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
