//! Cold-state paging for per-client round-boundary state.
//!
//! At 100k+ clients the resident-state wall is O(clients × model):
//! every client's Eq. 5 residual, Adam moments and RNG/schedule
//! positions stay in RAM between rounds even though partial
//! participation touches only a small cohort per round. The pager
//! spills cold [`ClientState`]s to disk and rehydrates them when their
//! client is selected, so the shard's resident set is bounded by
//! [`crate::fl::ExperimentConfig::resident_clients`] instead of the
//! client count.
//!
//! **No new format.** Each spilled state is one `net/frame` frame
//! (`FSNT` magic, length prefix, FNV-1a payload checksum) whose payload
//! is the exact client-state block the session snapshot codec and the
//! wire `STATE` pair already speak — a torn or bit-rotted spill file is
//! detected at load time with a descriptive error, never a
//! half-restored client.
//!
//! The pager is deliberately *not* an LRU itself: it is the spill
//! store. The shard decides what stays resident (its budget policy)
//! and calls [`ClientPager::store`]/[`ClientPager::load`] at round
//! boundaries. Paging is purely a memory knob — a paged run's outputs
//! are byte-identical to a fully-resident run, pinned by the paging
//! legs in `tests/integration_session.rs`.
//!
//! Spill files are ephemeral per run: durable checkpoints still carry
//! the full client-state set (the coordinator collects it over the
//! `STATE` pair), so crash/`--resume` never reads a spill directory —
//! a resumed shard re-pages from the installed state.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::fl::ClientState;
use crate::net::frame;
use crate::net::wire::{self, Rd};

/// Spill-file extension (`client-<id>.fcs`, "fsfl client state").
const PAGE_EXT: &str = ".fcs";

/// A directory of spilled per-client states, one checksummed frame
/// file each (see the module docs for the format and the resident-set
/// contract).
pub struct ClientPager {
    dir: PathBuf,
    /// Ids currently spilled (the in-memory index; spill files are
    /// ephemeral per run, so no directory scan is ever needed).
    spilled: BTreeSet<usize>,
    /// Whether this pager created `dir` and should remove it on drop.
    created_dir: bool,
    /// Reused encode buffer (steady-state spills allocate nothing
    /// beyond file I/O).
    buf: Vec<u8>,
}

impl ClientPager {
    /// Open (creating if needed) a spill directory. If the directory
    /// did not exist, the pager owns it and removes it on drop
    /// (best-effort); a pre-existing directory is left in place.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let created_dir = !dir.exists();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating pager dir {}", dir.display()))?;
        Ok(Self {
            dir,
            spilled: BTreeSet::new(),
            created_dir,
            buf: Vec::new(),
        })
    }

    /// The directory spill files live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Spill file path for one client id.
    fn page_path(&self, id: usize) -> PathBuf {
        self.dir.join(format!("client-{id:08}{PAGE_EXT}"))
    }

    /// How many clients are currently spilled.
    pub fn len(&self) -> usize {
        self.spilled.len()
    }

    /// Whether nothing is spilled.
    pub fn is_empty(&self) -> bool {
        self.spilled.is_empty()
    }

    /// Whether `id`'s state is currently spilled.
    pub fn contains(&self, id: usize) -> bool {
        self.spilled.contains(&id)
    }

    /// The spilled client ids, ascending.
    pub fn ids(&self) -> impl Iterator<Item = usize> + '_ {
        self.spilled.iter().copied()
    }

    /// Spill one client state (overwriting any previous spill of the
    /// same id). The write is a plain create-and-write — spill files
    /// are ephemeral per run, so the snapshot store's atomic
    /// tmp-rename discipline would buy nothing here; torn writes are
    /// still *detected* at load time by the frame checksum.
    pub fn store(&mut self, st: &ClientState) -> Result<()> {
        self.buf.clear();
        wire::put_client_state(&mut self.buf, st);
        let path = self.page_path(st.id);
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating spill file {}", path.display()))?;
        frame::write_frame(&mut f, &self.buf)
            .with_context(|| format!("spilling client {} to {}", st.id, path.display()))?;
        self.spilled.insert(st.id);
        Ok(())
    }

    /// Rehydrate one spilled client state. The frame layer verifies the
    /// checksum, the payload decodes through the shared client-state
    /// codec, and the decoded id must match the requested one — any
    /// mismatch is a descriptive error, never a half-restored client.
    pub fn load(&mut self, id: usize) -> Result<ClientState> {
        if !self.spilled.contains(&id) {
            return Err(anyhow!("client {id} is not spilled in this pager"));
        }
        let path = self.page_path(id);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading spill file {}", path.display()))?;
        let mut r = bytes.as_slice();
        self.buf.clear();
        let got = frame::read_frame(&mut r, &mut self.buf, frame::MAX_PAYLOAD)
            .with_context(|| format!("spill file {}", path.display()))?;
        if !got {
            return Err(anyhow!("spill file {} is empty", path.display()));
        }
        let mut rd = Rd::new(&self.buf);
        let st = wire::read_client_state(&mut rd)
            .with_context(|| format!("spill file {}", path.display()))?;
        rd.done()
            .with_context(|| format!("spill file {}", path.display()))?;
        if st.id != id {
            return Err(anyhow!(
                "spill file {} carries client {}, wanted {id}",
                path.display(),
                st.id
            ));
        }
        Ok(st)
    }

    /// Rehydrate and forget one spilled state (the page-in path: the
    /// state moves back to the resident set, so the spill file is
    /// stale the moment training touches the client again).
    pub fn take(&mut self, id: usize) -> Result<ClientState> {
        let st = self.load(id)?;
        self.remove(id)?;
        Ok(st)
    }

    /// Drop every spilled state (the install path: a state install is
    /// absolute, so any spill it does not cover is stale by
    /// definition).
    pub fn clear(&mut self) -> Result<()> {
        let ids: Vec<usize> = self.ids().collect();
        for id in ids {
            self.remove(id)?;
        }
        Ok(())
    }

    /// Drop one spilled state and its file.
    pub fn remove(&mut self, id: usize) -> Result<()> {
        if self.spilled.remove(&id) {
            let path = self.page_path(id);
            std::fs::remove_file(&path)
                .with_context(|| format!("removing spill file {}", path.display()))?;
        }
        Ok(())
    }
}

impl Drop for ClientPager {
    fn drop(&mut self) {
        // Best-effort GC: spill files never outlive the run on
        // purpose. Only a directory this pager created is removed
        // wholesale; a shared pre-existing directory just loses the
        // tracked spill files.
        for id in std::mem::take(&mut self.spilled) {
            let _ = std::fs::remove_file(self.page_path(id));
        }
        if self.created_dir {
            let _ = std::fs::remove_dir(&self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::OptSnapshot;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fsfl_pager_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn state(id: usize) -> ClientState {
        ClientState {
            id,
            rng: 0x5EED ^ id as u64,
            sched_global: 10 + id as u64,
            sched_period: 3,
            train_order: vec![2, 0, 1],
            residual: Some(vec![vec![0.5, -0.25], vec![1e-7]]),
            wopt: OptSnapshot {
                m: vec![vec![0.1]],
                v: vec![vec![0.2]],
                t: 7.0,
            },
            sopt: OptSnapshot {
                m: vec![],
                v: vec![],
                t: 0.0,
            },
        }
    }

    #[test]
    fn spill_and_rehydrate_round_trips_exactly() {
        let dir = tmp("roundtrip");
        let mut pager = ClientPager::open(&dir).unwrap();
        assert!(pager.is_empty());
        for id in [4usize, 0, 9] {
            pager.store(&state(id)).unwrap();
        }
        assert_eq!(pager.len(), 3);
        assert_eq!(pager.ids().collect::<Vec<_>>(), vec![0, 4, 9]);
        assert!(pager.contains(4) && !pager.contains(5));
        for id in [0usize, 4, 9] {
            assert_eq!(pager.load(id).unwrap(), state(id));
        }
        // take() rehydrates and forgets
        let st = pager.take(4).unwrap();
        assert_eq!(st, state(4));
        assert!(!pager.contains(4));
        assert!(pager.load(4).is_err(), "taken state must be gone");
        drop(pager);
        assert!(!dir.exists(), "pager-created dir must be removed on drop");
    }

    #[test]
    fn overwrite_keeps_the_newest_state() {
        let dir = tmp("overwrite");
        let mut pager = ClientPager::open(&dir).unwrap();
        pager.store(&state(2)).unwrap();
        let mut newer = state(2);
        newer.sched_global = 99;
        newer.rng = 0xABCD;
        pager.store(&newer).unwrap();
        assert_eq!(pager.len(), 1);
        assert_eq!(pager.load(2).unwrap(), newer);
    }

    #[test]
    fn corruption_and_id_mismatch_are_descriptive_errors() {
        let dir = tmp("corrupt");
        let mut pager = ClientPager::open(&dir).unwrap();
        pager.store(&state(3)).unwrap();
        let path = pager.page_path(3);
        // truncation (torn write) → frame-layer error
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = format!("{:#}", pager.load(3).unwrap_err());
        assert!(err.contains("mid-frame"), "undescriptive: {err}");
        // bit flip → checksum error
        let mut flipped = bytes.clone();
        let mid = flipped.len() - 4;
        flipped[mid] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        let err = format!("{:#}", pager.load(3).unwrap_err());
        assert!(
            err.contains("checksum") || err.contains("magic") || err.contains("oversized"),
            "undescriptive: {err}"
        );
        // a frame that decodes but carries the wrong id
        let other = state(8);
        let mut payload = Vec::new();
        wire::put_client_state(&mut payload, &other);
        let mut f = std::fs::File::create(&path).unwrap();
        frame::write_frame(&mut f, &payload).unwrap();
        drop(f);
        let err = format!("{:#}", pager.load(3).unwrap_err());
        assert!(err.contains("carries client 8"), "undescriptive: {err}");
        // loading an id that was never spilled fails up front
        assert!(pager.load(7).is_err());
    }

    #[test]
    fn remove_is_idempotent_and_preexisting_dirs_survive_drop() {
        let dir = tmp("remove");
        std::fs::create_dir_all(&dir).unwrap();
        {
            let mut pager = ClientPager::open(&dir).unwrap();
            pager.store(&state(1)).unwrap();
            pager.remove(1).unwrap();
            assert!(pager.is_empty());
            pager.remove(1).unwrap(); // no-op, no error
            pager.store(&state(5)).unwrap();
            pager.store(&state(6)).unwrap();
            pager.clear().unwrap();
            assert!(pager.is_empty());
            assert!(!pager.page_path(5).exists());
        }
        assert!(dir.exists(), "pre-existing dir must survive pager drop");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
