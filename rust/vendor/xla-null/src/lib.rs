//! Null PJRT backend.
//!
//! The production runtime drives jax-lowered HLO through the `xla` crate
//! (xla-rs) and its PJRT C-API bindings. That crate needs the
//! `xla_extension` C++ distribution, which the offline build image does
//! not carry. This crate mirrors the exact API surface
//! `fsfl::runtime` + the benches consume so the whole workspace builds,
//! unit-tests and benches everywhere; every *backend* entry point
//! (client construction, HLO parsing, compilation, execution) returns a
//! clean [`Error`] that callers already propagate as `anyhow` errors.
//!
//! Pure host-side [`Literal`] plumbing (construction, reshape, readback)
//! is implemented for real so data-marshalling code stays testable.
//!
//! To run on a real backend, point the `xla` path dependency in
//! `rust/Cargo.toml` at xla-rs ≥ 0.1.6 — no fsfl source changes needed.

use std::fmt;

/// Error type matching xla-rs' `Error` role (Display + Debug only).
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT backend unavailable (fsfl built against the null xla backend; \
             point the `xla` path dependency at xla-rs to enable compute)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Whether this build can actually execute HLO (false: null backend).
pub const BACKEND_AVAILABLE: bool = false;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Elements a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_le(bytes: &[u8]) -> Self;
    const SIZE: usize;
}

impl NativeType for f32 {
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().unwrap())
    }
    const SIZE: usize = 4;
}

/// Host-side tensor value (shape + raw little-endian bytes).
#[derive(Debug, Clone)]
pub struct Literal {
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let numel: usize = shape.iter().product::<usize>().max(1);
        if data.len() != numel * 4 {
            return Err(Error(format!(
                "literal: {} bytes for shape {shape:?} (want {})",
                data.len(),
                numel * 4
            )));
        }
        Ok(Self {
            shape: shape.to_vec(),
            data: data.to_vec(),
        })
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: Vec::new(),
            data: v.to_le_bytes().to_vec(),
        }
    }

    pub fn vec1(v: &[f32]) -> Self {
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Self {
            shape: vec![v.len()],
            data,
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let numel: usize = dims.iter().map(|&d| d.max(0) as usize).product();
        if numel * 4 != self.data.len() {
            return Err(Error(format!("reshape to {dims:?}: element count mismatch")));
        }
        Ok(Self {
            shape: dims.iter().map(|&d| d as usize).collect(),
            data: self.data.clone(),
        })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.chunks_exact(T::SIZE).map(T::from_le).collect())
    }

    /// Tuple readback: the null backend never produces tuples (nothing
    /// executes), so this only exists for API parity.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("literal to_tuple"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("literal to_tuple1"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HLO parse"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "null".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

pub struct PjRtLoadedExecutable;

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, -2.5, 3.25]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        let r = l.reshape(&[3, 1]).unwrap();
        assert_eq!(r.shape(), &[3, 1]);
        assert!(l.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn backend_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err().to_string();
        assert!(e.contains("PJRT backend unavailable"));
    }

    #[test]
    fn untyped_literal_checks_size() {
        let bytes = [0u8; 8];
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &bytes).is_ok());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).is_err());
    }
}
