//! Minimal offline drop-in for the subset of `anyhow` this repository
//! uses: [`Error`], [`Result`], the [`anyhow!`] macro and the
//! [`Context`] extension trait. The build registry has no network
//! access, so the real crate cannot be fetched; this shim keeps the
//! public surface source-compatible (swap the path dependency for the
//! crates.io release to get backtraces and downcasting).

use std::fmt;

/// An error chain: a message plus an optional boxed cause. `{:#}`
/// (alternate) formatting prints the whole chain `a: b: c`, matching
/// how the CLI reports failures.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self {
            msg: ctx.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    pub fn root_cause(&self) -> &Error {
        self.chain().last().unwrap()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, e) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket conversion below coherent (same trick as the
// real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.unwrap()
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("format {args}")` — construct an [`Error`] from a format
/// string (or from any single `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `bail!(...)` — early-return an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to `Result`/`Option` values (anyhow's extension trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
    }

    #[test]
    fn context_chains_alternate_display() {
        let r: Result<()> = Err(io_err().into());
        let e = r.with_context(|| "opening file").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening file: gone");
        assert_eq!(format!("{e}"), "opening file");
        assert_eq!(e.root_cause().to_string(), "gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }
}
