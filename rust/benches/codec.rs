//! DeepCABAC codec throughput (L3 hot path #1).
//!
//! Regenerates the compression-side numbers behind Table 2: bytes per
//! update at several sparsities, encode/decode MB/s, and the row-skip
//! ablation (structured vs scattered zeros) from DESIGN.md.

use std::sync::Arc;
use std::time::Duration;

use fsfl::benchkit::{bench_auto, smoke_mode};
use fsfl::compression::cabac::{
    decode_update, decode_update_with, encode_update, encode_update_into, DecodeScratch,
    EncodeScratch,
};
use fsfl::compression::QuantConfig;
use fsfl::data::XorShiftRng;
use fsfl::model::params::Delta;
use fsfl::model::{Group, Kind, Manifest, TensorSpec};

fn manifest(rows: usize, row_len: usize) -> Arc<Manifest> {
    Arc::new(Manifest {
        model: "bench".into(),
        variant: "bench".into(),
        classes: 2,
        input: vec![2, 2, 1],
        batch: 1,
        param_count: rows * row_len,
        scale_count: 0,
        tensors: vec![TensorSpec {
            name: "w".into(),
            shape: vec![rows, row_len],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "l".into(),
            out_ch: Some(rows),
            scale_for: None,
        }],
    })
}

fn delta_with_sparsity(m: &Arc<Manifest>, sparsity: f64, structured: bool, seed: u64) -> Delta {
    let (rows, row_len) = m.tensors[0].rows().unwrap();
    let mut rng = XorShiftRng::new(seed);
    let mut d = Delta::zeros(m.clone());
    if structured {
        let dense_rows = ((1.0 - sparsity) * rows as f64).round() as usize;
        for r in 0..dense_rows {
            for c in 0..row_len {
                d.tensors[0][r * row_len + c] = rng.normal() * 0.01;
            }
        }
    } else {
        for x in d.tensors[0].iter_mut() {
            if (rng.next_f32() as f64) > sparsity {
                *x = rng.normal() * 0.01;
            }
        }
    }
    d
}

fn main() {
    let smoke = smoke_mode();
    let (rows, row_len) = if smoke { (64, 256) } else { (512, 1024) };
    let budget = if smoke {
        Duration::from_millis(50)
    } else {
        Duration::from_secs(2)
    };
    let m = manifest(rows, row_len); // 512k-element update (~vgg11 conv stack)
    let q = QuantConfig::default();
    let step = |spec: &TensorSpec| q.step_for(spec);
    let numel = rows * row_len;
    let raw_mb = (numel * 4) as f64 / 1e6;
    println!(
        "codec bench: {rows}x{row_len} f32 update ({raw_mb:.1} MB raw){}\n",
        if smoke { " [smoke]" } else { "" }
    );

    let sparsities: &[f64] = if smoke { &[0.96] } else { &[0.0, 0.5, 0.9, 0.96, 0.99] };
    for &sparsity in sparsities {
        let d = delta_with_sparsity(&m, sparsity, false, 1);
        let (bytes, _, stats) = encode_update(&d, &[0], &step);
        let r = bench_auto(
            &format!("encode sparsity={sparsity:.2} ({} B)", bytes.len()),
            budget,
            || encode_update(&d, &[0], &step),
        );
        r.print_throughput(raw_mb, "MB(raw)");
        // steady-state path: recycled scratch + output buffers
        let mut scratch = EncodeScratch::default();
        let mut deq = fsfl::model::params::Delta::zeros(m.clone());
        let mut dst = Vec::new();
        let r = bench_auto(
            &format!("encode_into sparsity={sparsity:.2} (0-alloc)"),
            budget,
            || encode_update_into(&d, &[0], &step, true, &mut scratch, &mut deq, &mut dst),
        );
        r.print_throughput(raw_mb, "MB(raw)");
        let r = bench_auto(
            &format!("decode sparsity={sparsity:.2}"),
            budget,
            || decode_update(&bytes, &m).unwrap(),
        );
        r.print_throughput(raw_mb, "MB(raw)");
        let mut dscratch = DecodeScratch::default();
        let mut out = fsfl::model::params::Delta::zeros(m.clone());
        let r = bench_auto(
            &format!("decode_into sparsity={sparsity:.2} (0-alloc)"),
            budget,
            || decode_update_with(&bytes, &mut out, &mut dscratch).unwrap(),
        );
        r.print_throughput(raw_mb, "MB(raw)");
        println!(
            "    ratio {:.1}x  nonzero {}  rows skipped {}/{}\n",
            (numel * 4) as f64 / bytes.len() as f64,
            stats.nonzero,
            stats.rows_skipped,
            stats.rows_total
        );
    }

    // Ablation: structured (whole zero rows) vs scattered zeros at equal
    // element sparsity — the row-skip flag should make structured far
    // smaller and faster.
    println!("-- row-skip ablation @ 96% sparsity --");
    for (label, structured) in [("structured-rows", true), ("scattered", false)] {
        let d = delta_with_sparsity(&m, 0.96, structured, 2);
        let (bytes, _, _) = encode_update(&d, &[0], &step);
        let r = bench_auto(
            &format!("encode {label} ({} B)", bytes.len()),
            budget,
            || encode_update(&d, &[0], &step),
        );
        r.print_throughput(raw_mb, "MB(raw)");
    }

    // Ablation: context adaptation on/off — DeepCABAC's probability
    // models are where the entropy win comes from.
    println!("\n-- context-adaptation ablation @ 96% sparsity --");
    let d = delta_with_sparsity(&m, 0.96, false, 3);
    for (label, adaptive) in [("adaptive-contexts", true), ("frozen-contexts", false)] {
        let (bytes, _, _) =
            fsfl::compression::cabac::encode_update_opts(&d, &[0], &step, adaptive);
        println!(
            "{label:<30} {:>9} B  ({:.1}x vs raw)",
            bytes.len(),
            (numel * 4) as f64 / bytes.len() as f64
        );
    }
}
