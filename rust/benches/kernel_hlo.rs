//! L1 kernel bench at the HLO level: the Pallas scaled matmul (both
//! schedules) vs the pure-XLA dot reference, executed through the same
//! PJRT 0.5.1 backend the production runtime uses. This isolates the
//! interpret-mode overhead from model-level effects.
//!
//! Shape: 2048x1152x128 (VGG11 conv3-like im2col matmul).

use std::time::Duration;

use fsfl::benchkit::bench_auto;
use fsfl::data::XorShiftRng;
use fsfl::runtime::Runtime;

fn artifacts_root() -> std::path::PathBuf {
    std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping kernel_hlo bench: {e}");
            return;
        }
    };
    let dir = artifacts_root().join("_kernelbench");
    let shape = std::fs::read_to_string(dir.join("shape.tsv")).expect("make artifacts first");
    let dims: Vec<usize> = shape
        .split_whitespace()
        .map(|s| s.parse().unwrap())
        .collect();
    let (b, k, m) = (dims[0], dims[1], dims[2]);
    let flops = 2.0 * b as f64 * k as f64 * m as f64;
    println!("kernel_hlo bench: [{b},{k}] @ [{k},{m}] * s  ({:.2} GFLOP)\n", flops / 1e9);

    let mut rng = XorShiftRng::new(1);
    let x: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
    let s: Vec<f32> = (0..m).map(|_| 1.0 + rng.next_f32()).collect();
    let xl = xla::Literal::vec1(&x).reshape(&[b as i64, k as i64]).unwrap();
    let wl = xla::Literal::vec1(&w).reshape(&[k as i64, m as i64]).unwrap();
    let sl = xla::Literal::vec1(&s);

    let mut reference: Option<Vec<f32>> = None;
    for file in [
        "matmul_xla_ref.hlo.txt",
        "scaled_matmul_single.hlo.txt",
        "scaled_matmul_mxu.hlo.txt",
    ] {
        let path = dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = rt.client().compile(&comp).unwrap();
        // correctness cross-check against the XLA reference
        let out = exe.execute(&[&xl, &wl, &sl]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                let max_err = r
                    .iter()
                    .zip(&out)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_err < 1e-2, "{file}: max err {max_err}");
            }
        }
        let r = bench_auto(file, Duration::from_secs(3), || {
            exe.execute(&[&xl, &wl, &sl]).unwrap()
        });
        r.print_throughput(flops / 1e9, "GFLOP");
    }
}
