//! PJRT step latency (L2/L3 boundary): train / scale / eval / predict
//! per-call wall-clock incl. literal marshalling, per model variant.
//!
//! Run after `make artifacts`. Skips variants without artifacts.

use std::time::Duration;

use fsfl::benchkit::bench_auto;
use fsfl::data::{batches, Dataset, TaskKind, TaskSpec};
use fsfl::model::Group;
use fsfl::runtime::{ModelRuntime, Optimizer, Runtime};

fn artifacts_root() -> std::path::PathBuf {
    std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping runtime_step bench: {e}");
            return;
        }
    };
    for variant in ["tiny_cnn", "vgg11_thin", "resnet8", "mobilenet_tiny"] {
        let dir = artifacts_root().join(variant);
        if !dir.exists() {
            eprintln!("skip {variant}: no artifacts");
            continue;
        }
        let mr = ModelRuntime::open(&rt, artifacts_root(), variant).unwrap();
        let man = mr.manifest.clone();
        let task = match man.classes {
            2 => TaskKind::XrayLike,
            20 => TaskKind::VocLike,
            _ => TaskKind::CifarLike,
        };
        let spec = TaskSpec::new(task, man.input[0], man.input[2], 7);
        let ds = Dataset::generate(&spec, man.batch, 0);
        let order: Vec<usize> = (0..ds.len()).collect();
        let b = batches(&ds, &order, man.batch).remove(0);
        let mut params = mr.init_params().unwrap();
        let mut wopt = mr.opt_state(Group::Weight);
        let mut sopt = mr.opt_state(Group::Scale);

        println!(
            "\n== {variant}: {} params, batch {} ==",
            man.param_count, man.batch
        );
        bench_auto("train_step (adam)", Duration::from_secs(3), || {
            mr.train_step(&mut params, &mut wopt, Optimizer::Adam, 1e-3, &b.x, &b.y)
                .unwrap()
        })
        .print();
        bench_auto("scale_step (adam)", Duration::from_secs(3), || {
            mr.scale_step(&mut params, &mut sopt, Optimizer::Adam, 1e-2, &b.x, &b.y)
                .unwrap()
        })
        .print();
        bench_auto("eval_step", Duration::from_secs(2), || {
            mr.eval_step(&params, &b.x, &b.y).unwrap()
        })
        .print();
        bench_auto("predict_step", Duration::from_secs(2), || {
            mr.predict_step(&params, &b.x).unwrap()
        })
        .print();
        println!("total executions: {}", mr.exec_calls.borrow());
    }
}
