//! Sparsification kernels (Eq. 2, Eq. 3, top-k) on update-sized tensors.

use std::time::Duration;

use fsfl::benchkit::{bench_auto, smoke_mode};
use fsfl::compression::sparsify::{
    apply_structured, apply_topk, apply_topk_with, apply_unstructured, row_means_into,
    structured_threshold, threshold_from_means, unstructured_threshold,
};
use fsfl::data::XorShiftRng;

fn main() {
    let smoke = smoke_mode();
    let n = if smoke { 1 << 14 } else { 1 << 20 }; // 1M elements ≈ vgg11_thin update
    let rows = if smoke { 64 } else { 1024 };
    let row_len = n / rows;
    let budget = if smoke {
        Duration::from_millis(50)
    } else {
        Duration::from_secs(2)
    };
    let mut rng = XorShiftRng::new(1);
    let base: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
    let mb = (n * 4) as f64 / 1e6;
    println!("sparsify bench: {n} elements ({mb:.1} MB){}\n", if smoke { " [smoke]" } else { "" });

    bench_auto("eq2 threshold (fused sum/sumsq pass)", budget, || {
        unstructured_threshold(&base, 1.0, 4.88e-4)
    })
    .print_throughput(mb, "MB");

    let theta = unstructured_threshold(&base, 1.0, 4.88e-4);
    bench_auto("eq2 apply (zeroing pass)", budget, || {
        let mut t = base.clone();
        apply_unstructured(&mut t, theta)
    })
    .print_throughput(mb, "MB");

    bench_auto("eq3 threshold (row means)", budget, || {
        structured_threshold(&base, rows, row_len, 1.0)
    })
    .print_throughput(mb, "MB");

    let ts = structured_threshold(&base, rows, row_len, 1.0);
    bench_auto("eq3 apply (recomputed means)", budget, || {
        let mut t = base.clone();
        apply_structured(&mut t, rows, row_len, ts)
    })
    .print_throughput(mb, "MB");

    // shared-row-means path (the production pipeline): one means pass
    // feeds both the threshold and the zeroing
    let mut means = Vec::new();
    bench_auto("eq3 threshold+apply (shared means)", budget, || {
        let mut t = base.clone();
        row_means_into(&t, rows, row_len, &mut means);
        let theta = threshold_from_means(&means, 1.0);
        fsfl::compression::sparsify::apply_structured_with_means(&mut t, rows, row_len, theta, &means)
    })
    .print_throughput(mb, "MB");

    bench_auto("topk 96% (select_nth)", budget, || {
        let mut t = base.clone();
        apply_topk(&mut t, 0.96)
    })
    .print_throughput(mb, "MB");

    let mut mags = Vec::new();
    bench_auto("topk 96% (recycled scratch)", budget, || {
        let mut t = base.clone();
        apply_topk_with(&mut t, 0.96, &mut mags)
    })
    .print_throughput(mb, "MB");

    bench_auto("clone only (baseline)", budget, || base.clone())
        .print_throughput(mb, "MB");
}
