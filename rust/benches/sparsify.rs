//! Sparsification kernels (Eq. 2, Eq. 3, top-k) on update-sized tensors.

use std::time::Duration;

use fsfl::benchkit::bench_auto;
use fsfl::compression::sparsify::{
    apply_structured, apply_topk, apply_unstructured, structured_threshold,
    unstructured_threshold,
};
use fsfl::data::XorShiftRng;

fn main() {
    let n = 1 << 20; // 1M elements ≈ vgg11_thin update
    let rows = 1024;
    let row_len = n / rows;
    let mut rng = XorShiftRng::new(1);
    let base: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
    let mb = (n * 4) as f64 / 1e6;
    println!("sparsify bench: {n} elements ({mb:.1} MB)\n");

    bench_auto("eq2 threshold (mean/std pass)", Duration::from_secs(2), || {
        unstructured_threshold(&base, 1.0, 4.88e-4)
    })
    .print_throughput(mb, "MB");

    let theta = unstructured_threshold(&base, 1.0, 4.88e-4);
    bench_auto("eq2 apply (zeroing pass)", Duration::from_secs(2), || {
        let mut t = base.clone();
        apply_unstructured(&mut t, theta)
    })
    .print_throughput(mb, "MB");

    bench_auto("eq3 threshold (row means)", Duration::from_secs(2), || {
        structured_threshold(&base, rows, row_len, 1.0)
    })
    .print_throughput(mb, "MB");

    let ts = structured_threshold(&base, rows, row_len, 1.0);
    bench_auto("eq3 apply (row zeroing)", Duration::from_secs(2), || {
        let mut t = base.clone();
        apply_structured(&mut t, rows, row_len, ts)
    })
    .print_throughput(mb, "MB");

    bench_auto("topk 96% (select_nth)", Duration::from_secs(2), || {
        let mut t = base.clone();
        apply_topk(&mut t, 0.96)
    })
    .print_throughput(mb, "MB");

    bench_auto("clone only (baseline)", Duration::from_secs(2), || base.clone())
        .print_throughput(mb, "MB");
}
