//! End-to-end FL round throughput, split by plane.
//!
//! Section 1 (runs everywhere, including CI): the **codec plane** of a
//! round — per-client sparsify → quantize → DeepCABAC encode, server-side
//! decode of the actual bitstreams, FedAvg aggregation — driven through
//! the real `RoundLane`/`WorkerPool`/`Server` machinery at several pool
//! widths. Asserts byte-identical streams across widths, counts heap
//! allocations per steady-state round (the zero-allocation pipeline
//! claim), and emits `BENCH_fl_round.json` so future PRs have a perf
//! trajectory to diff against.
//!
//! Section 2 (runs everywhere): **staged vs pipelined round schedule** —
//! the same codec round driven through `fl::scheduler` with a calibrated
//! busy-loop standing in for PJRT compute. Asserts byte-identical
//! outputs across modes and records both rounds/sec figures so the
//! overlap win shows in the perf trajectory.
//!
//! Section 3 (needs `make artifacts` + a real PJRT backend): the full
//! Table 2 execution path per protocol, as before.
//!
//! `cargo bench --bench fl_round -- --test` runs a seconds-long smoke
//! subset (the CI gate).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use fsfl::bench::summary::{self, Hist};
use fsfl::benchkit::{smoke_mode, Report};
use fsfl::compression::{QuantConfig, SparsifyMode};
use fsfl::data::{TaskKind, XorShiftRng};
use fsfl::exec::WorkerPool;
use fsfl::fl::scheduler::{self, ComputePlane, ScheduleMode};
use fsfl::fl::{Experiment, ExperimentConfig, Protocol, ProtocolConfig, RoundLane, Server};
use fsfl::metrics::fmt_bytes;
use fsfl::model::params::Delta;
use fsfl::model::{Group, Kind, Manifest, ParamSet, TensorSpec};
use fsfl::runtime::Runtime;

// ---------------------------------------------------------------------------
// Counting allocator: measures steady-state allocations per codec round.
// ---------------------------------------------------------------------------

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Section 1: codec-plane round (no PJRT needed)
// ---------------------------------------------------------------------------

fn bench_manifest(rows: usize, row_len: usize) -> Arc<Manifest> {
    Arc::new(Manifest {
        model: "bench".into(),
        variant: "bench".into(),
        classes: 2,
        input: vec![2, 2, 1],
        batch: 1,
        param_count: rows * row_len,
        scale_count: 0,
        tensors: vec![TensorSpec {
            name: "w".into(),
            shape: vec![rows, row_len],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "l".into(),
            out_ch: Some(rows),
            scale_for: None,
        }],
    })
}

struct CodecBench {
    lanes: Vec<RoundLane>,
    base: Vec<Delta>,
    server: Server,
    broadcast: Delta,
    pcfg: ProtocolConfig,
    update_idx: Vec<usize>,
}

impl CodecBench {
    fn new(manifest: &Arc<Manifest>, clients: usize) -> Self {
        let mut rng = XorShiftRng::new(0xBE7C);
        let base: Vec<Delta> = (0..clients)
            .map(|_| {
                let mut d = Delta::zeros(manifest.clone());
                for x in d.tensors[0].iter_mut() {
                    // ~90% of elements below the dynamic threshold
                    *x = rng.normal() * 6e-4;
                }
                d
            })
            .collect();
        let params = ParamSet::new(
            manifest.clone(),
            manifest.tensors.iter().map(|t| vec![0.0; t.numel()]).collect(),
        )
        .unwrap();
        let pcfg = Protocol::Fsfl.config(
            SparsifyMode::Dynamic { delta: 1.0, gamma: 1.0 },
            QuantConfig::default(),
        );
        Self {
            lanes: (0..clients).map(|_| RoundLane::new(manifest.clone())).collect(),
            base,
            server: Server::new(params, None),
            broadcast: Delta::zeros(manifest.clone()),
            pcfg,
            update_idx: vec![0],
        }
    }

    /// One codec-plane round: fan encode + wire-decode out over `pool`,
    /// then aggregate. Returns total upstream bytes.
    fn round(&mut self, pool: &WorkerPool) -> usize {
        for (k, lane) in self.lanes.iter_mut().enumerate() {
            lane.begin(k);
            lane.raw.copy_from(&self.base[k]);
        }
        let pcfg = &self.pcfg;
        let update_idx = &self.update_idx;
        pool.run_mut(&mut self.lanes, |_, lane| {
            lane.encode_upstream(pcfg, update_idx)
        });
        pool.run_mut(&mut self.lanes, |_, lane| lane.finish_round(pcfg, &[]));
        let updates: Vec<&Delta> = self.lanes.iter().map(|l| &l.decoded).collect();
        self.server.aggregate_into(&updates, &mut self.broadcast);
        self.lanes.iter().map(|l| l.up_bytes).sum()
    }
}

fn codec_plane_section(report: &mut Report, smoke: bool) {
    let (rows, row_len) = if smoke { (64, 256) } else { (256, 1024) };
    let clients = 8;
    let rounds = if smoke { 3 } else { 20 };
    let manifest = bench_manifest(rows, row_len);
    let raw_mb = (rows * row_len * 4 * clients) as f64 / 1e6;
    println!(
        "codec-plane round: {clients} clients x {rows}x{row_len} f32 ({raw_mb:.1} MB raw/round)\n"
    );
    println!(
        "{:>7} {:>12} {:>14} {:>16} {:>14}",
        "workers", "rounds/s", "ms/round", "encode µs/client", "allocs/round"
    );

    report.int("clients", clients as u64);
    report.int("update_elems", (rows * row_len) as u64);
    report.int("rounds", rounds as u64);

    let widths = [1usize, 2, 4];
    let mut per_width: Vec<(usize, f64)> = Vec::new();
    let mut reference: Option<(Vec<Vec<u8>>, u64)> = None;
    for &w in &widths {
        let pool = WorkerPool::new(w);
        let mut bench = CodecBench::new(&manifest, clients);
        // warm-up round grows every buffer to steady-state size
        let up_bytes = bench.round(&pool);

        // byte-identical across pool widths (and vs the serial reference)
        let streams: Vec<Vec<u8>> = bench.lanes.iter().map(|l| l.stream_w.clone()).collect();
        let decoded_sum: u64 = bench.lanes.iter().map(|l| l.decoded.checksum()).fold(0, u64::wrapping_add);
        match &reference {
            None => reference = Some((streams, decoded_sum)),
            Some((ref_streams, ref_sum)) => {
                assert_eq!(&streams, ref_streams, "pool width {w}: bitstreams diverged");
                assert_eq!(decoded_sum, *ref_sum, "pool width {w}: decodes diverged");
            }
        }

        let mut round_ms = Hist::new();
        let a0 = allocs();
        let t0 = Instant::now();
        for _ in 0..rounds {
            let r0 = Instant::now();
            bench.round(&pool);
            round_ms.push(r0.elapsed().as_secs_f64() * 1e3);
        }
        let secs = t0.elapsed().as_secs_f64();
        let allocs_per_round = (allocs() - a0) as f64 / rounds as f64;

        // encode-stage-only timing (stage 2 of the round pipeline)
        let t1 = Instant::now();
        for _ in 0..rounds {
            for (k, lane) in bench.lanes.iter_mut().enumerate() {
                lane.begin(k);
                lane.raw.copy_from(&bench.base[k]);
            }
            let pcfg = &bench.pcfg;
            let update_idx = &bench.update_idx;
            pool.run_mut(&mut bench.lanes, |_, lane| {
                lane.encode_upstream(pcfg, update_idx)
            });
        }
        let encode_us_per_client =
            t1.elapsed().as_secs_f64() * 1e6 / (rounds * clients) as f64;

        let rps = rounds as f64 / secs;
        println!(
            "{:>7} {:>12.2} {:>14.2} {:>16.1} {:>14.1}   (up {}/round)",
            pool.workers(),
            rps,
            secs * 1000.0 / rounds as f64,
            encode_us_per_client,
            allocs_per_round,
            fmt_bytes(up_bytes)
        );
        per_width.push((pool.workers(), rps));

        let mut sub = Report::new();
        sub.int("workers", pool.workers() as u64)
            .num("rounds_per_sec", rps)
            .num("ms_per_round", secs * 1000.0 / rounds as f64)
            .num("encode_us_per_client", encode_us_per_client)
            .num("allocs_per_round", allocs_per_round)
            .int("up_bytes_per_round", up_bytes as u64)
            .obj("round_ms", round_ms.report());
        report.obj(&format!("pool{}", pool.workers()), sub);
    }

    let serial = per_width.iter().find(|(w, _)| *w == 1).map(|&(_, r)| r);
    let par = per_width.iter().find(|(w, _)| *w == 4).map(|&(_, r)| r);
    if let (Some(serial), Some(par)) = (serial, par) {
        let speedup = par / serial;
        println!("\ncodec-plane speedup 4 workers vs serial: {speedup:.2}x");
        report.num("speedup_4_vs_1", speedup);
    }
}

// ---------------------------------------------------------------------------
// Section 2: staged vs pipelined round schedule (no PJRT needed)
// ---------------------------------------------------------------------------

/// Deterministic compute spin: the stand-in for a thread-affine PJRT
/// step while measuring scheduler overlap.
fn spin(iters: u64) -> f64 {
    let mut x = 0.0f64;
    let mut i = 0u64;
    while i < iters {
        x += (i as f64).sqrt();
        i += 1;
    }
    x
}

/// Synthetic compute plane: fixed per-client raw update + calibrated
/// busy-loops for the train/scale stages.
struct SimCompute {
    base: Vec<Delta>,
    train_iters: u64,
    scale_iters: u64,
}

impl ComputePlane for SimCompute {
    fn train(&mut self, lane: &mut RoundLane) -> fsfl::Result<()> {
        lane.raw.copy_from(&self.base[lane.client]);
        std::hint::black_box(spin(self.train_iters));
        Ok(())
    }

    fn scale(&mut self, lane: &mut RoundLane) -> fsfl::Result<()> {
        std::hint::black_box(spin(self.scale_iters));
        Ok(())
    }
}

fn scheduler_section(report: &mut Report, smoke: bool) {
    let (rows, row_len) = if smoke { (64, 256) } else { (256, 1024) };
    let clients = 8usize;
    let rounds = if smoke { 3 } else { 15 };
    let manifest = bench_manifest(rows, row_len);
    let pcfg = Protocol::Fsfl.config(
        SparsifyMode::Dynamic { delta: 1.0, gamma: 1.0 },
        QuantConfig::default(),
    );
    let update_idx = vec![0usize];
    let scale_idx: Vec<usize> = Vec::new();
    let order: Vec<usize> = (0..clients).collect();
    let pool = WorkerPool::new(4);

    let mut rng = XorShiftRng::new(0x5EED);
    let base: Vec<Delta> = (0..clients)
        .map(|_| {
            let mut d = Delta::zeros(manifest.clone());
            for x in d.tensors[0].iter_mut() {
                *x = rng.normal() * 6e-4;
            }
            d
        })
        .collect();

    // Calibrate the busy-loop so "compute" costs ~0.8 ms per train stage
    // (same order as the codec stages — the regime where overlap pays).
    let t0 = Instant::now();
    std::hint::black_box(spin(1_000_000));
    let per_iter = t0.elapsed().as_secs_f64() / 1e6;
    let train_iters = (0.0008 / per_iter.max(1e-12)) as u64;
    let scale_iters = train_iters / 2;

    println!(
        "\nround schedule: {clients} clients x {rows}x{row_len} f32, \
         sim compute {train_iters} iters/train (pool {})\n",
        pool.workers()
    );
    println!("{:>10} {:>12} {:>14}", "schedule", "rounds/s", "ms/round");

    let run_mode = |mode: ScheduleMode| -> (f64, Hist, Vec<Vec<u8>>) {
        let mut lanes: Vec<RoundLane> = (0..clients)
            .map(|_| RoundLane::new(manifest.clone()))
            .collect();
        let mut compute = SimCompute {
            base: base.clone(),
            train_iters,
            scale_iters,
        };
        // warm-up round grows buffers and faults in code paths
        scheduler::run_round(
            mode, &pool, &mut compute, &mut lanes, &order, &pcfg, &update_idx, &scale_idx,
        )
        .unwrap();
        let streams: Vec<Vec<u8>> = lanes.iter().map(|l| l.stream_w.clone()).collect();
        let mut round_ms = Hist::new();
        let t0 = Instant::now();
        for _ in 0..rounds {
            let r0 = Instant::now();
            scheduler::run_round(
                mode, &pool, &mut compute, &mut lanes, &order, &pcfg, &update_idx, &scale_idx,
            )
            .unwrap();
            round_ms.push(r0.elapsed().as_secs_f64() * 1e3);
        }
        let secs = t0.elapsed().as_secs_f64();
        let rps = rounds as f64 / secs;
        println!(
            "{:>10} {:>12.2} {:>14.2}",
            format!("{mode:?}").to_lowercase(),
            rps,
            secs * 1000.0 / rounds as f64
        );
        (rps, round_ms, streams)
    };

    let (staged_rps, staged_ms, staged_streams) = run_mode(ScheduleMode::Staged);
    let (pipelined_rps, pipelined_ms, pipelined_streams) = run_mode(ScheduleMode::Pipelined);
    assert_eq!(
        staged_streams, pipelined_streams,
        "pipelined schedule changed the bitstreams"
    );
    let speedup = pipelined_rps / staged_rps;
    println!("\npipelined vs staged: {speedup:.2}x");

    let mut sub = Report::new();
    sub.num("staged_rounds_per_sec", staged_rps)
        .num("pipelined_rounds_per_sec", pipelined_rps)
        .num("pipeline_speedup", speedup)
        .bool("pipeline_overlap_wins", pipelined_rps >= staged_rps)
        .int("sim_train_iters", train_iters)
        .int("clients", clients as u64)
        .obj("staged_round_ms", staged_ms.report())
        .obj("pipelined_round_ms", pipelined_ms.report());
    report.obj("scheduler", sub);
}

// ---------------------------------------------------------------------------
// Section 3: full experiment path (needs PJRT + artifacts)
// ---------------------------------------------------------------------------

fn artifacts_root() -> std::path::PathBuf {
    std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn experiment_section() {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\nskipping end-to-end section: {e}");
            return;
        }
    };
    if !artifacts_root().join("tiny_cnn").join("manifest.tsv").exists() {
        println!("\nskipping end-to-end section: no artifacts (run `make artifacts`)");
        return;
    }
    println!("\nfl_round bench: tiny_cnn, 8 clients, 64 train samples each\n");
    println!(
        "{:<20} {:>8} {:>10} {:>12} {:>12} {:>12}",
        "protocol", "workers", "rounds/s", "ms/round", "up B/round", "train share"
    );
    for protocol in Protocol::ALL {
        for workers in [1usize, 4] {
            let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, protocol);
            cfg.artifacts_root = artifacts_root();
            cfg.rounds = 6;
            cfg.clients = 8;
            cfg.train_per_client = 64;
            cfg.val_per_client = 16;
            cfg.test_samples = 32;
            cfg.scale_epochs = 1;
            cfg.codec_workers = workers;
            let mut exp = Experiment::build(&rt, cfg).unwrap();
            let t0 = Instant::now();
            let log = exp.run().unwrap();
            let secs = t0.elapsed().as_secs_f64();
            let rounds = log.rounds.len() as f64;
            let train_ms: u128 = log.rounds.iter().map(|r| r.train_ms + r.scale_ms).sum();
            let up: usize = log.rounds.iter().map(|r| r.up_bytes).sum();
            println!(
                "{:<20} {:>8} {:>10.2} {:>12.1} {:>12} {:>11.0}%",
                protocol.name(),
                workers,
                rounds / secs,
                secs * 1000.0 / rounds,
                fmt_bytes(up / log.rounds.len()),
                train_ms as f64 / (secs * 1000.0) * 100.0
            );
        }
    }
}

fn main() {
    let smoke = smoke_mode();
    let mut report = Report::new();
    // Same versioned envelope as BENCH_scenarios.json, so one schema
    // gate (and one CI diff script) covers both artifacts.
    summary::file_header(&mut report, "fl_round", if smoke { "smoke" } else { "full" });

    codec_plane_section(&mut report, smoke);
    scheduler_section(&mut report, smoke);
    if !smoke {
        experiment_section();
    }

    // Smoke mode exercises the very same writer + schema gate as a full
    // run, but cleans up after itself unless FSFL_BENCH_OUT asks CI to
    // keep the artifact.
    let explicit = std::env::var("FSFL_BENCH_OUT").ok();
    let ephemeral = smoke && explicit.is_none();
    let out = explicit.unwrap_or_else(|| {
        if ephemeral {
            "BENCH_fl_round.smoke.tmp.json".into()
        } else {
            "BENCH_fl_round.json".into()
        }
    });
    report.write(&out).expect("writing the bench report");
    let text = std::fs::read_to_string(&out).expect("reading back the bench report");
    let parsed = fsfl::bench::json::parse(&text).expect("bench report is valid JSON");
    summary::validate_summary(&parsed).expect("bench report passes the schema gate");
    if ephemeral {
        std::fs::remove_file(&out).expect("removing the smoke-mode temp report");
        println!("\nreport validated (smoke mode, temp file removed)");
    } else {
        println!("\nreport → {out}");
    }
}
