//! End-to-end FL round latency per protocol (the Table 2 execution path):
//! local epoch + sparsify + quantize + encode + decode + aggregate +
//! broadcast + central eval, on tiny_cnn.

use std::time::Instant;

use fsfl::data::TaskKind;
use fsfl::fl::{Experiment, ExperimentConfig, Protocol};
use fsfl::metrics::fmt_bytes;
use fsfl::runtime::Runtime;

fn artifacts_root() -> std::path::PathBuf {
    std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() {
    let rt = Runtime::cpu().expect("pjrt cpu");
    println!("fl_round bench: tiny_cnn, 2 clients, 64 train samples each\n");
    println!(
        "{:<20} {:>10} {:>12} {:>12} {:>12}",
        "protocol", "rounds/s", "ms/round", "up B/round", "train share"
    );
    for protocol in Protocol::ALL {
        let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, protocol);
        cfg.artifacts_root = artifacts_root();
        cfg.rounds = 6;
        cfg.train_per_client = 64;
        cfg.val_per_client = 16;
        cfg.test_samples = 32;
        cfg.scale_epochs = 1;
        let mut exp = Experiment::build(&rt, cfg).unwrap();
        let t0 = Instant::now();
        let log = exp.run().unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let rounds = log.rounds.len() as f64;
        let train_ms: u128 = log.rounds.iter().map(|r| r.train_ms + r.scale_ms).sum();
        let up: usize = log.rounds.iter().map(|r| r.up_bytes).sum();
        println!(
            "{:<20} {:>10.2} {:>12.1} {:>12} {:>11.0}%",
            protocol.name(),
            rounds / secs,
            secs * 1000.0 / rounds,
            fmt_bytes(up / log.rounds.len()),
            train_ms as f64 / (secs * 1000.0) * 100.0
        );
    }
}
