//! Quickstart: a 5-round FSFL run on the tiny model + synthetic CIFAR-like
//! task. Shows the whole stack end to end: PJRT artifact loading, local
//! training, dynamic sparsification, DeepCABAC encoding, scale-factor
//! sub-epochs, federated averaging.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use fsfl::coordinator;
use fsfl::data::TaskKind;
use fsfl::fl::{Experiment, ExperimentConfig, Protocol};
use fsfl::metrics::fmt_bytes;
use fsfl::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, Protocol::Fsfl);
    cfg.rounds = 5;
    cfg.train_per_client = 96;
    cfg.name = "quickstart".into();

    let mut exp = Experiment::build(&rt, cfg)?;
    println!(
        "model {}: {} params, {} scale factors, batch {}",
        exp.mr.manifest.model,
        exp.mr.manifest.param_count,
        exp.mr.manifest.scale_count,
        exp.mr.batch_size()
    );

    let log = exp.run_with(coordinator::print_round)?;
    assert!(exp.replicas_in_sync(), "client/server replicas diverged");
    println!(
        "\nbest accuracy {:.3}, total upstream {}, downstream {}",
        log.best_accuracy(),
        fmt_bytes(log.total_bytes(true)),
        fmt_bytes(log.total_bytes(false) - log.total_bytes(true)),
    );
    Ok(())
}
