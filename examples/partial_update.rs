//! Partial vs end-to-end updates (paper Fig. 2 VGG16 panel): the partial
//! configuration transmits only the classifier head (BatchNorm + two
//! dense layers) plus its scale factors — a couple hundred scales — yet
//! converges comparably while sending a fraction of the bytes.
//!
//! ```bash
//! cargo run --release --example partial_update -- --rounds 10
//! ```

use anyhow::Result;

use fsfl::cli::Flags;
use fsfl::coordinator::print_round;
use fsfl::data::TaskKind;
use fsfl::fl::{Experiment, ExperimentConfig, Protocol};
use fsfl::metrics::fmt_bytes;
use fsfl::model::Group;
use fsfl::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = Flags::parse(&args)?;
    let rounds: usize = flags.get_or("rounds", 10)?;
    flags.reject_unknown()?;

    let rt = Runtime::cpu()?;
    println!("== partial_update: vgg16 end2end vs classifier-only, {rounds} rounds ==\n");

    let mut summaries = Vec::new();
    for (variant, label) in [("vgg16_head", "end2end"), ("vgg16_partial", "partial")] {
        let mut cfg = ExperimentConfig::quick(variant, TaskKind::XrayLike, Protocol::Fsfl);
        cfg.name = format!("partial_update-{label}");
        cfg.rounds = rounds;
        cfg.train_per_client = 128;
        cfg.val_per_client = 32;
        cfg.test_samples = 128;
        cfg.scale_epochs = 2;

        println!("--- {label} ({variant}) ---");
        let mut exp = Experiment::build(&rt, cfg)?;
        let man = exp.mr.manifest.clone();
        let trainable: usize = man
            .group_indices(Group::Weight)
            .iter()
            .chain(man.group_indices(Group::Scale).iter())
            .map(|&i| man.tensors[i].numel())
            .sum();
        println!(
            "{} params total, {} trainable, {} scale factors",
            man.param_count,
            trainable,
            man.scale_count
        );
        let log = exp.run_with(print_round)?;
        assert!(exp.replicas_in_sync());
        std::fs::create_dir_all("results").ok();
        log.write_csv(format!("results/{}.csv", log.name))?;
        summaries.push((label, log.best_accuracy(), log.total_bytes(true)));
        println!();
    }

    println!("== summary ==");
    for (label, acc, bytes) in &summaries {
        println!("{label:<10} best acc {acc:.3}   Σ up {}", fmt_bytes(*bytes));
    }
    println!("\npartial updates transmit only the head: expect a large byte gap");
    Ok(())
}
