//! The paper's hospital scenario (Fig. 2 bottom-right): hospitals jointly
//! train a pneumonia detector; both the clients→server updates AND the
//! server→clients broadcast are sparsified, quantized and DeepCABAC-coded
//! (bidirectional compression, halved coarse step per Sec. 5.1). Reports
//! F1 (imbalanced 2-class task) alongside accuracy.
//!
//! ```bash
//! cargo run --release --example bidirectional_xray -- --rounds 10
//! ```

use anyhow::Result;

use fsfl::cli::Flags;
use fsfl::coordinator::print_round;
use fsfl::data::TaskKind;
use fsfl::fl::{Experiment, ExperimentConfig, Protocol};
use fsfl::metrics::fmt_bytes;
use fsfl::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = Flags::parse(&args)?;
    let rounds: usize = flags.get_or("rounds", 10)?;
    let clients: usize = flags.get_or("clients", 4)?; // "a number of hospitals"
    flags.reject_unknown()?;

    let rt = Runtime::cpu()?;
    println!("== bidirectional_xray: vgg16_head, {clients} hospitals, {rounds} rounds ==\n");

    let mut summaries = Vec::new();
    for (bidir, label) in [(false, "unidirectional"), (true, "bidirectional")] {
        let mut cfg = ExperimentConfig::quick("vgg16_head", TaskKind::XrayLike, Protocol::Fsfl);
        cfg.name = format!("bidirectional_xray-{label}");
        cfg.clients = clients;
        cfg.rounds = rounds;
        cfg.bidirectional = bidir;
        cfg.train_per_client = 128;
        cfg.val_per_client = 32;
        cfg.test_samples = 128;
        cfg.scale_epochs = 2;

        println!("--- {label} ---");
        let mut exp = Experiment::build(&rt, cfg)?;
        let log = exp.run_with(print_round)?;
        assert!(exp.replicas_in_sync());
        std::fs::create_dir_all("results").ok();
        log.write_csv(format!("results/{}.csv", log.name))?;
        let best_f1 = log.rounds.iter().map(|r| r.f1).fold(0.0, f64::max);
        summaries.push((
            label,
            log.best_accuracy(),
            best_f1,
            log.total_bytes(true),
            log.total_bytes(false),
        ));
        println!();
    }

    println!("== summary ==");
    for (label, acc, f1, up, total) in &summaries {
        println!(
            "{label:<16} acc {acc:.3}  F1 {f1:.3}  up {}  up+down {}",
            fmt_bytes(*up),
            fmt_bytes(*total)
        );
    }
    Ok(())
}
