//! End-to-end validation driver (EXPERIMENTS.md §E2E): federated training
//! of the paper's thinned VGG11 (0.85M params, 1002 scale factors) on the
//! synthetic CIFAR-like task, FSFL vs the sparse and quantized baselines.
//!
//! This is the run recorded in EXPERIMENTS.md — it exercises every layer:
//! Pallas kernels inside the AOT HLO, the PJRT runtime, dynamic
//! sparsification, DeepCABAC, scale sub-epochs and federated averaging,
//! and logs the central model's loss/accuracy curve per round.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example federated_cifar -- --rounds 20 --clients 2
//! ```

use anyhow::Result;

use fsfl::cli::Flags;
use fsfl::coordinator::print_round;
use fsfl::data::TaskKind;
use fsfl::fl::{Experiment, ExperimentConfig, Protocol};
use fsfl::metrics::fmt_bytes;
use fsfl::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = Flags::parse(&args)?;
    let rounds: usize = flags.get_or("rounds", 15)?;
    let clients: usize = flags.get_or("clients", 2)?;
    let variant = flags.str_or("variant", "vgg11_thin");
    let per_client: usize = flags.get_or("train-per-client", 256)?;
    let protocols = flags
        .list::<String>("protocols")?
        .unwrap_or_else(|| vec!["fsfl".into(), "sparse".into(), "fedavg_q".into()]);
    flags.reject_unknown()?;

    let rt = Runtime::cpu()?;
    println!("== federated_cifar: {variant}, {clients} clients, {rounds} rounds ==\n");

    let mut summaries = Vec::new();
    for pname in &protocols {
        let protocol: Protocol = pname.parse()?;
        let mut cfg = ExperimentConfig::quick(&variant, TaskKind::CifarLike, protocol);
        cfg.name = format!("federated_cifar-{pname}");
        cfg.clients = clients;
        cfg.rounds = rounds;
        cfg.train_per_client = per_client;
        cfg.val_per_client = 64;
        cfg.test_samples = 160;
        cfg.scale_epochs = 2;

        println!("--- {} ---", protocol.name());
        let mut exp = Experiment::build(&rt, cfg)?;
        let log = exp.run_with(print_round)?;
        assert!(exp.replicas_in_sync());
        std::fs::create_dir_all("results").ok();
        log.write_csv(format!("results/{}.csv", log.name))?;
        summaries.push((
            protocol.name().to_string(),
            log.best_accuracy(),
            log.total_bytes(true),
        ));
        println!();
    }

    println!("== summary (accuracy vs upstream traffic) ==");
    for (name, acc, bytes) in &summaries {
        println!("{name:<20} best acc {acc:.3}   Σ up {}", fmt_bytes(*bytes));
    }
    Ok(())
}
