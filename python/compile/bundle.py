"""Tensor-bundle binary format shared with rust/src/model/io.rs.

Layout (little-endian):
  magic   : 4 bytes  b"FSTB"
  version : u32      (1)
  count   : u32
  per tensor:
    name_len : u32
    name     : utf-8 bytes
    ndim     : u32
    dims     : u32 * ndim
    dtype    : u32 (0 = f32)
    data     : f32 * prod(dims), little-endian
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"FSTB"
VERSION = 1
DTYPE_F32 = 0


def write_bundle(path: str, tensors: list[tuple[str, np.ndarray]]):
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<I", DTYPE_F32))
            f.write(arr.astype("<f4").tobytes())


def read_bundle(path: str) -> list[tuple[str, np.ndarray]]:
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (dtype,) = struct.unpack("<I", f.read(4))
            assert dtype == DTYPE_F32
            n = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(4 * n), "<f4").reshape(dims)
            out.append((name, arr))
    return out
