"""L2 facade: the paper's jax model fwd/bwd, calling the L1 kernels.

The actual definitions live in:
  * :mod:`compile.layers` -- layer primitives + parameter manifest builder
  * :mod:`compile.zoo`    -- the model families (VGG/ResNet/MobileNet, thinned)
  * :mod:`compile.steps`  -- train / scale-train / eval step functions

This module re-exports the public build surface used by aot.py & tests.
"""

from .zoo import REGISTRY, Model, build  # noqa: F401
from .steps import (  # noqa: F401
    group_indices,
    init_opt_state,
    make_eval_step,
    make_step,
)
