"""AOT pipeline: lower every step function of every model variant to HLO
*text* and emit the runtime artifact set consumed by the rust coordinator.

Per variant, ``artifacts/<variant>/`` contains:
  train_step.hlo.txt        Adam over the weight group (S frozen)
  train_step_sgd.hlo.txt    SGD+momentum variant
  scale_step_adam.hlo.txt   Adam over the scale group (W + BN state frozen)
  scale_step_sgd.hlo.txt    SGD+momentum over the scale group
  eval_step.hlo.txt
  manifest.json             tensor order/kinds/groups + wire signatures
  init.bin                  initial parameter values (tensor bundle)

HLO **text** is the interchange format, not ``lowered.compile()`` /
serialized protos: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 (the version behind the rust
``xla`` crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Python runs ONLY here (``make artifacts``); never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import zoo
from .bundle import write_bundle
from .steps import group_indices, make_eval_step, make_predict_step, make_step

# Default artifact set: (variant, builder kwargs, batch)
# Batch sizes are deliberately small -- everything executes on the CPU
# PJRT client; the FL dynamics, not per-step FLOPs, are the experiment.
DEFAULT_VARIANTS = {
    "tiny_cnn": dict(kwargs=dict(classes=10, hw=16), batch=16),
    "vgg11_thin": dict(kwargs=dict(classes=10, hw=32), batch=32),
    "resnet8": dict(kwargs=dict(classes=20, hw=32), batch=32),
    "mobilenet_tiny": dict(kwargs=dict(classes=20, hw=32), batch=32),
    "mobilenet_tiny_full": dict(kwargs=dict(classes=20, hw=32), batch=32),
    "vgg16_head": dict(kwargs=dict(classes=2, hw=32), batch=32),
    "vgg16_partial": dict(kwargs=dict(classes=2, hw=32), batch=32),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_variant(name: str, out_dir: str, *, batch: int, kwargs: dict, quiet=False):
    t0 = time.time()
    model = zoo.build(name, **kwargs)
    os.makedirs(out_dir, exist_ok=True)
    specs = model.specs
    h, w, c = model.input_shape
    x_s = _sds((batch, h, w, c))
    y_s = _sds((batch, model.classes))
    p_s = [_sds(sp.shape) for sp in specs]
    scalar = _sds(())

    def opt_shapes(group):
        return [_sds(specs[i].shape) for i in group_indices(specs, group)]

    files = {}

    def emit(fname, fn, args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        files[fname] = {
            "bytes": len(text),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        if not quiet:
            print(f"  {fname:26s} {len(text)/1e6:8.2f} MB")

    def wrap(step):
        n = len(specs)
        g = step.group_size

        def fn(*args):
            params = list(args[:n])
            ms = list(args[n : n + g])
            vs = list(args[n + g : n + 2 * g])
            t, lr, x, y = args[n + 2 * g :]
            return step(params, ms, vs, t, lr, x, y)

        return fn

    wopt = opt_shapes("weight")
    sopt = opt_shapes("scale")
    train_args = (*p_s, *wopt, *wopt, scalar, scalar, x_s, y_s)
    scale_args = (*p_s, *sopt, *sopt, scalar, scalar, x_s, y_s)

    emit(
        "train_step.hlo.txt",
        wrap(make_step(model, group="weight", opt="adam", train_bn=True)),
        train_args,
    )
    emit(
        "train_step_sgd.hlo.txt",
        wrap(make_step(model, group="weight", opt="sgd", train_bn=True)),
        train_args,
    )
    emit(
        "scale_step_adam.hlo.txt",
        wrap(make_step(model, group="scale", opt="adam", train_bn=False)),
        scale_args,
    )
    emit(
        "scale_step_sgd.hlo.txt",
        wrap(make_step(model, group="scale", opt="sgd", train_bn=False)),
        scale_args,
    )

    ev = make_eval_step(model)

    def eval_fn(*args):
        return ev(list(args[: len(specs)]), args[-2], args[-1])

    emit("eval_step.hlo.txt", eval_fn, (*p_s, x_s, y_s))

    pr = make_predict_step(model)

    def predict_fn(*args):
        return pr(list(args[: len(specs)]), args[-1])

    emit("predict_step.hlo.txt", predict_fn, (*p_s, x_s))

    manifest = {
        "model": model.name,
        "variant": name,
        "classes": model.classes,
        "input": list(model.input_shape),
        "batch": batch,
        "param_count": int(sum(np.prod(sp.shape) for sp in specs)),
        "scale_count": int(
            sum(np.prod(specs[i].shape) for i in group_indices(specs, "scale"))
        ),
        "tensors": [sp.to_json() for sp in specs],
        "groups": {
            g: group_indices(specs, g) for g in ("weight", "scale", "state", "frozen")
        },
        "wire": {
            "train": "params + m[weight] + v[weight] + t + lr + x + y -> params + m + v + t + loss + correct",
            "scale": "params + m[scale] + v[scale] + t + lr + x + y -> params + m + v + t + loss + correct",
            "eval": "params + x + y -> loss + correct",
        },
        "files": files,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # line-based mirror consumed by rust/src/model/manifest.rs (the offline
    # environment has no serde; manifest.json stays for humans/tools)
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write(f"model\t{model.name}\n")
        f.write(f"variant\t{name}\n")
        f.write(f"classes\t{model.classes}\n")
        f.write("input\t" + " ".join(str(d) for d in model.input_shape) + "\n")
        f.write(f"batch\t{batch}\n")
        f.write(f"param_count\t{manifest['param_count']}\n")
        f.write(f"scale_count\t{manifest['scale_count']}\n")
        for sp in specs:
            dims = " ".join(str(d) for d in sp.shape)
            f.write(
                "tensor\t"
                f"{sp.name}\t{sp.kind}\t{sp.group}\t{sp.layer}\t"
                f"{sp.out_ch if sp.out_ch is not None else '-'}\t"
                f"{sp.scale_for if sp.scale_for else '-'}\t{dims}\n"
            )
    write_bundle(
        os.path.join(out_dir, "init.bin"),
        [(sp.name, model.values[sp.name]) for sp in specs],
    )
    if not quiet:
        print(
            f"  {name}: {manifest['param_count']} params "
            f"({manifest['scale_count']} scales), {time.time()-t0:.1f}s"
        )
    return manifest


def lower_kernel_bench(out_dir: str, quiet=False):
    """Kernel-only HLOs for the rust-side L1 bench (benches/kernel_hlo.rs):
    the pallas scaled matmul under both schedules plus the pure-XLA dot
    reference, at a conv3-of-VGG11-like shape (2048x1152x128)."""
    import importlib

    smod = importlib.import_module("compile.kernels.scaled_matmul")
    os.makedirs(out_dir, exist_ok=True)
    b, k, m = 2048, 1152, 128
    x_s = _sds((b, k))
    w_s = _sds((k, m))
    s_s = _sds((m,))

    def emit(fname, fn):
        lowered = jax.jit(fn).lower(x_s, w_s, s_s)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        if not quiet:
            print(f"  kernelbench/{fname}")

    emit(
        "scaled_matmul_single.hlo.txt",
        lambda x, w, s: (smod.pallas_scaled_matmul(x, w, s, schedule="single"),),
    )
    emit(
        "scaled_matmul_mxu.hlo.txt",
        lambda x, w, s: (smod.pallas_scaled_matmul(x, w, s, schedule="mxu"),),
    )
    emit(
        "matmul_xla_ref.hlo.txt",
        lambda x, w, s: (jnp.matmul(x, w) * s[None, :],),
    )
    with open(os.path.join(out_dir, "shape.tsv"), "w") as f:
        f.write(f"{b}\t{k}\t{m}\n")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root dir")
    ap.add_argument(
        "--models",
        default=",".join(DEFAULT_VARIANTS),
        help="comma-separated variant list",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    index = {}
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        cfg = DEFAULT_VARIANTS[name]
        if not args.quiet:
            print(f"[aot] lowering {name} ...", flush=True)
        man = lower_variant(
            name,
            os.path.join(args.out, name),
            batch=cfg["batch"],
            kwargs=cfg["kwargs"],
            quiet=args.quiet,
        )
        index[name] = {
            "batch": cfg["batch"],
            "classes": man["classes"],
            "input": man["input"],
            "param_count": man["param_count"],
            "scale_count": man["scale_count"],
        }
    lower_kernel_bench(os.path.join(args.out, "_kernelbench"), quiet=args.quiet)
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    with open(os.path.join(args.out, "index.tsv"), "w") as f:
        for name, info in index.items():
            f.write(f"{name}\t{info['batch']}\t{info['classes']}\t{info['param_count']}\t{info['scale_count']}\n")
    print(f"[aot] wrote {len(index)} variants to {args.out}")


if __name__ == "__main__":
    sys.exit(main())
