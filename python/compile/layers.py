"""L2 layer primitives and the parameter/manifest builder.

Every conv / dense layer is stored in **im2col row layout**: a conv
weight is ``[M, Cin*k*k]`` (one row per filter) and a dense weight is
``[M, N]`` (one row per output neuron).  This is exactly the granularity
of the paper's structured sparsification (Eq. 3) and filter scaling
(Eq. 4), so the rust coordinator can treat "one row = one filter"
uniformly without knowing about convolutions.

Activations are NHWC.  Convs run as im2col + the L1 Pallas
``scaled_matmul`` kernel (scale fused in the matmul epilogue).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import scaled_matmul

BN_MOMENTUM = 0.9
BN_EPS = 1e-5


@dataclasses.dataclass
class TensorSpec:
    """One named parameter tensor; serialized into manifest.json."""

    name: str
    shape: tuple
    kind: str  # conv_w | dw_conv_w | dense_w | bias | bn_gamma | bn_beta |
    #            bn_mean | bn_var | scale
    group: str  # weight | scale | state | frozen
    layer: str  # owning layer prefix, e.g. "features.conv3"
    out_ch: Optional[int] = None  # M for row-structured tensors
    scale_for: Optional[str] = None  # for kind=scale: the scaled weight name

    def to_json(self):
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        return d


class Builder:
    """Registers parameters in a fixed order and initializes them.

    The registration order *is* the wire order: manifest.json, init.bin
    and every HLO step signature all use it.
    """

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.specs: list[TensorSpec] = []
        self.values: dict[str, np.ndarray] = {}

    def add(self, spec: TensorSpec, value: np.ndarray):
        assert spec.name not in self.values, f"duplicate tensor {spec.name}"
        assert tuple(value.shape) == tuple(spec.shape), (
            f"{spec.name}: {value.shape} != {spec.shape}"
        )
        self.specs.append(spec)
        self.values[spec.name] = np.asarray(value, np.float32)

    # -- layer constructors -------------------------------------------------

    def conv(
        self,
        layer: str,
        cin: int,
        cout: int,
        k: int,
        *,
        scale: bool = True,
        trainable: bool = True,
        bn: bool = True,
        bias: bool = True,
    ):
        wgroup = "weight" if trainable else "frozen"
        row = cin * k * k
        fan_in = row
        std = math.sqrt(2.0 / fan_in)  # He init (ReLU nets)
        w = self.rng.normal(0.0, std, size=(cout, row))
        self.add(
            TensorSpec(f"{layer}.w", (cout, row), "conv_w", wgroup, layer, cout),
            w,
        )
        if bias:
            self.add(
                TensorSpec(f"{layer}.b", (cout,), "bias", wgroup, layer, cout),
                np.zeros(cout),
            )
        if bn:
            self._bn(layer, cout, trainable)
        if scale:
            self._scale(layer, cout, trainable, f"{layer}.w")

    def dwconv(
        self,
        layer: str,
        c: int,
        k: int,
        *,
        scale: bool = True,
        trainable: bool = True,
    ):
        wgroup = "weight" if trainable else "frozen"
        std = math.sqrt(2.0 / (k * k))
        w = self.rng.normal(0.0, std, size=(c, k * k))
        self.add(
            TensorSpec(f"{layer}.w", (c, k * k), "dw_conv_w", wgroup, layer, c), w
        )
        self._bn(layer, c, trainable)
        if scale:
            self._scale(layer, c, trainable, f"{layer}.w")

    def dense(
        self,
        layer: str,
        nin: int,
        nout: int,
        *,
        scale: bool = True,
        trainable: bool = True,
        bias: bool = True,
    ):
        wgroup = "weight" if trainable else "frozen"
        std = math.sqrt(2.0 / nin)
        w = self.rng.normal(0.0, std, size=(nout, nin))
        self.add(
            TensorSpec(f"{layer}.w", (nout, nin), "dense_w", wgroup, layer, nout), w
        )
        if bias:
            self.add(
                TensorSpec(f"{layer}.b", (nout,), "bias", wgroup, layer, nout),
                np.zeros(nout),
            )
        if scale:
            self._scale(layer, nout, trainable, f"{layer}.w")

    def batchnorm(self, layer: str, c: int, *, trainable: bool = True):
        self._bn(layer, c, trainable)

    def _bn(self, layer: str, c: int, trainable: bool):
        wgroup = "weight" if trainable else "frozen"
        self.add(
            TensorSpec(f"{layer}.gamma", (c,), "bn_gamma", wgroup, layer, c),
            np.ones(c),
        )
        self.add(
            TensorSpec(f"{layer}.beta", (c,), "bn_beta", wgroup, layer, c),
            np.zeros(c),
        )
        # Running stats: always "state" (updated from batch statistics in
        # train_step, frozen during scale training per Algorithm 1).
        sgroup = "state" if trainable else "frozen"
        self.add(
            TensorSpec(f"{layer}.mean", (c,), "bn_mean", sgroup, layer, c),
            np.zeros(c),
        )
        self.add(
            TensorSpec(f"{layer}.var", (c,), "bn_var", sgroup, layer, c),
            np.ones(c),
        )

    def _scale(self, layer: str, c: int, trainable: bool, scale_for: str):
        group = "scale" if trainable else "frozen"
        self.add(
            TensorSpec(
                f"{layer}.s", (c,), "scale", group, layer, c, scale_for=scale_for
            ),
            np.ones(c),
        )


# ---------------------------------------------------------------------------
# Functional ops (used by zoo.py apply functions)
# ---------------------------------------------------------------------------


def im2col(x, k: int, stride: int, padding: str):
    """x: [B, H, W, C] -> patches [B*Ho*Wo, C*k*k] matching the conv_w row
    layout (the patch channel order of conv_general_dilated_patches, which
    is channel-major: c*k*k ordering [C, kh, kw])."""
    b = x.shape[0]
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(k, k),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, Ho, Wo, C*k*k]
    ho, wo = patches.shape[1], patches.shape[2]
    return patches.reshape(b * ho * wo, patches.shape[3]), (b, ho, wo)


def conv2d(vals, layer: str, x, *, k: int, stride: int = 1, padding: str = "SAME"):
    """Filter-scaled conv via im2col + the L1 Pallas kernel."""
    w = vals[f"{layer}.w"]  # [M, C*k*k]
    m = w.shape[0]
    patches, (b, ho, wo) = im2col(x, k, stride, padding)
    s = vals.get(f"{layer}.s")
    if s is None:
        s = jnp.ones((m,), jnp.float32)
    out = scaled_matmul(patches, w, s)  # [B*Ho*Wo, M]
    out = out.reshape(b, ho, wo, m)
    bias = vals.get(f"{layer}.b")
    if bias is not None:
        out = out + bias
    return out


def dwconv2d(vals, layer: str, x, *, k: int, stride: int = 1, padding: str = "SAME"):
    """Depthwise conv with per-channel scale folded into the kernel.

    Folding s into the depthwise kernel is mathematically identical to
    scaling the output channel (Eq. 4 for N=1 filters) and keeps a single
    conv op; jax differentiates it natively.
    """
    w = vals[f"{layer}.w"]  # [C, k*k]
    c = w.shape[0]
    s = vals.get(f"{layer}.s")
    if s is not None:
        w = w * s[:, None]
    kern = jnp.transpose(w.reshape(c, k, k), (1, 2, 0)).reshape(k, k, 1, c)
    return lax.conv_general_dilated(
        x,
        kern,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def dense(vals, layer: str, x):
    w = vals[f"{layer}.w"]  # [M, N]
    s = vals.get(f"{layer}.s")
    if s is None:
        s = jnp.ones((w.shape[0],), jnp.float32)
    out = scaled_matmul(x, w, s)
    bias = vals.get(f"{layer}.b")
    if bias is not None:
        out = out + bias
    return out


def batchnorm(vals, layer: str, x, *, train: bool, new_state: dict):
    """BN over NHWC (axis=-1 features) or [B, F] dense activations."""
    gamma, beta = vals[f"{layer}.gamma"], vals[f"{layer}.beta"]
    axes = tuple(range(x.ndim - 1))
    if train:
        mu = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_state[f"{layer}.mean"] = (
            BN_MOMENTUM * vals[f"{layer}.mean"] + (1 - BN_MOMENTUM) * mu
        )
        new_state[f"{layer}.var"] = (
            BN_MOMENTUM * vals[f"{layer}.var"] + (1 - BN_MOMENTUM) * var
        )
    else:
        mu, var = vals[f"{layer}.mean"], vals[f"{layer}.var"]
    inv = lax.rsqrt(var + BN_EPS)
    return (x - mu) * inv * gamma + beta


def maxpool(x, k: int = 2, stride: int = 2):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, k, k, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def global_avgpool(x):
    return jnp.mean(x, axis=(1, 2))


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)
