"""L2 step functions AOT-lowered to HLO and driven by the rust runtime.

Wire convention (shared with rust/src/runtime/ and manifest.json):

  train_step   inputs : params... , m[weight]..., v[weight]..., t, lr, x, y
               outputs: params'..., m'...,        v'...,        t', loss, correct
  scale_step   inputs : params... , m[scale]...,  v[scale]...,  t, lr, x, y
               outputs: params'..., m'...,        v'...,        t', loss, correct
  eval_step    inputs : params..., x, y
               outputs: loss, correct

``params`` is the full ordered tensor list from the manifest; each step
returns the *full* list with only its group changed (weight+state for
train, scale for scale_step).  ``t`` is the f32 Adam step count, ``lr``
the schedule-controlled learning rate (rust owns the schedule, Fig. 1).
``x`` is [B, H, W, C] f32, ``y`` one-hot [B, classes] f32.

Algorithm 1 semantics:
  * train_step freezes S (its grads are simply not taken),
  * scale_step freezes W *and the BatchNorm running stats* -- the model
    is applied with train=False so BN normalizes with the frozen
    running statistics while only S receives gradients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
SGD_MOMENTUM = 0.9


def group_indices(specs, group: str):
    return [i for i, sp in enumerate(specs) if sp.group == group]


def softmax_xent(logits, y_onehot):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def count_correct(logits, y_onehot):
    return jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y_onehot, axis=-1)).astype(
            jnp.float32
        )
    )


def _adam(p, g, m, v, t, lr):
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m / (1 - ADAM_B1**t)
    vhat = v / (1 - ADAM_B2**t)
    return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def _sgd(p, g, m, v, t, lr):
    """SGD with momentum 0.9 (paper Appendix A); v is carried unchanged so
    the wire signature matches Adam."""
    m = SGD_MOMENTUM * m + g
    return p - lr * m, m, v


OPTIMIZERS = {"adam": _adam, "sgd": _sgd}


def make_step(model, *, group: str, opt: str, train_bn: bool):
    """Build a step that optimizes exactly the tensors in ``group``.

    group="weight", train_bn=True  -> the paper's client W training
    group="scale",  train_bn=False -> Algorithm 1 scale sub-epoch
    """
    specs = model.specs
    names = [sp.name for sp in specs]
    gidx = group_indices(specs, group)
    gnames = [names[i] for i in gidx]
    sidx = group_indices(specs, "state")
    update = OPTIMIZERS[opt]

    def step(params, ms, vs, t, lr, x, y):
        vals = dict(zip(names, params))

        def loss_fn(gvals):
            local = dict(vals)
            local.update(zip(gnames, gvals))
            new_state: dict = {}
            logits = model.apply(local, x, train=train_bn, new_state=new_state)
            loss = softmax_xent(logits, y)
            return loss, (new_state, count_correct(logits, y))

        gvals = [params[i] for i in gidx]
        (loss, (new_state, correct)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(gvals)

        t1 = t + 1.0
        new_params = list(params)
        new_ms, new_vs = list(ms), list(vs)
        for slot, (i, g) in enumerate(zip(gidx, grads)):
            p, m, v = update(params[i], g, ms[slot], vs[slot], t1, lr)
            new_params[i], new_ms[slot], new_vs[slot] = p, m, v
        if train_bn:
            for i in sidx:
                if names[i] in new_state:
                    new_params[i] = new_state[names[i]]
        return (*new_params, *new_ms, *new_vs, t1, loss, correct)

    step.group_size = len(gidx)
    step.group_indices = gidx
    return step


def make_eval_step(model):
    names = [sp.name for sp in model.specs]

    def eval_step(params, x, y):
        vals = dict(zip(names, params))
        logits = model.apply(vals, x, train=False, new_state={})
        return softmax_xent(logits, y), count_correct(logits, y)

    return eval_step


def make_predict_step(model):
    """Top-1 predictions as f32 [B] (rust computes confusion/F1 from these)."""
    names = [sp.name for sp in model.specs]

    def predict_step(params, x):
        vals = dict(zip(names, params))
        logits = model.apply(vals, x, train=False, new_state={})
        return (jnp.argmax(logits, axis=-1).astype(jnp.float32),)

    return predict_step


def init_opt_state(model, group: str):
    import numpy as np

    gidx = group_indices(model.specs, group)
    return [np.zeros(model.specs[i].shape, np.float32) for i in gidx]
