"""L2 model zoo: the paper's network families, thinned for this testbed.

Paper models -> zoo equivalents (Section 5.1 substitutions, see
DESIGN.md):

  * VGG11_CIFAR10 (thinned, 0.8M params) -> ``vgg11_thin``: the exact
    [32, 64, 128, 128, 128, 128, 128, 128] conv widths and 128-wide
    dense layers from the paper, for 32x32 inputs.
  * ResNet18                              -> ``resnet8``: 3 stages of
    basic residual blocks with projection shortcuts.
  * MobileNetV2                           -> ``mobilenet_tiny``:
    inverted residual blocks (expand pointwise / depthwise / project
    pointwise), with the paper's two scale placements: ``full`` (every
    conv) and ``project_only`` (only the output conv of each block,
    Fig. 2 "full-S" comparison).
  * VGG16 partial update                  -> ``vgg16_head``: VGG-style
    feature stack + the paper's classifier head (BatchNorm + two dense
    layers); ``partial=True`` freezes everything but the head, which is
    exactly the paper's "258 scaling factors" setting.
  * ``tiny_cnn``: a 2-conv model for fast tests/CI presets.

Every model is a ``Model`` with an ordered parameter manifest (see
layers.Builder) and a functional ``apply(values, x, train, new_state)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from . import layers as L


@dataclasses.dataclass
class Model:
    name: str
    builder: L.Builder
    apply: Callable  # (vals, x, train, new_state) -> logits
    input_shape: tuple  # (H, W, C)
    classes: int

    @property
    def specs(self):
        return self.builder.specs

    @property
    def values(self):
        return self.builder.values


# ---------------------------------------------------------------------------
# tiny_cnn
# ---------------------------------------------------------------------------


def tiny_cnn(classes: int = 10, in_ch: int = 3, hw: int = 16, seed: int = 0):
    b = L.Builder(seed)
    b.conv("c1", in_ch, 8, 3)
    b.conv("c2", 8, 16, 3)
    feat = 16
    b.dense("fc", feat, classes)

    def apply(v, x, train, new_state):
        x = L.relu(L.batchnorm(v, "c1", L.conv2d(v, "c1", x, k=3), train=train, new_state=new_state))
        x = L.maxpool(x)
        x = L.relu(L.batchnorm(v, "c2", L.conv2d(v, "c2", x, k=3), train=train, new_state=new_state))
        x = L.global_avgpool(x)
        return L.dense(v, "fc", x)

    return Model("tiny_cnn", b, apply, (hw, hw, in_ch), classes)


# ---------------------------------------------------------------------------
# vgg11_thin — the paper's VGG11_CIFAR10
# ---------------------------------------------------------------------------

VGG11_WIDTHS = [32, 64, 128, 128, 128, 128, 128, 128]
# VGG11 layout: conv-pool / conv-pool / conv-conv-pool / conv-conv-pool /
# conv-conv-pool
VGG11_POOL_AFTER = {0, 1, 3, 5, 7}


def vgg11_thin(classes: int = 10, in_ch: int = 3, hw: int = 32, seed: int = 0):
    b = L.Builder(seed)
    cin = in_ch
    for i, w in enumerate(VGG11_WIDTHS):
        b.conv(f"conv{i}", cin, w, 3)
        cin = w
    b.dense("fc1", 128, 128)
    b.dense("fc2", 128, classes)

    def apply(v, x, train, new_state):
        for i in range(len(VGG11_WIDTHS)):
            x = L.conv2d(v, f"conv{i}", x, k=3)
            x = L.batchnorm(v, f"conv{i}", x, train=train, new_state=new_state)
            x = L.relu(x)
            if i in VGG11_POOL_AFTER:
                x = L.maxpool(x)
        x = x.reshape(x.shape[0], -1)  # 1x1x128 after 5 pools on 32x32
        x = L.relu(L.dense(v, "fc1", x))
        return L.dense(v, "fc2", x)

    return Model("vgg11_thin", b, apply, (hw, hw, in_ch), classes)


# ---------------------------------------------------------------------------
# resnet8
# ---------------------------------------------------------------------------


def resnet8(classes: int = 20, in_ch: int = 3, hw: int = 32, seed: int = 0):
    b = L.Builder(seed)
    widths = [16, 32, 64]
    b.conv("stem", in_ch, widths[0], 3)
    cin = widths[0]
    for si, w in enumerate(widths):
        pfx = f"s{si}"
        b.conv(f"{pfx}.conv1", cin, w, 3)
        b.conv(f"{pfx}.conv2", w, w, 3)
        if cin != w:
            b.conv(f"{pfx}.proj", cin, w, 1, scale=False, bn=True, bias=False)
        cin = w
    b.dense("fc", widths[-1], classes)

    def apply(v, x, train, new_state):
        def bn(name, t):
            return L.batchnorm(v, name, t, train=train, new_state=new_state)

        x = L.relu(bn("stem", L.conv2d(v, "stem", x, k=3)))
        cin_l = widths[0]
        for si, w in enumerate(widths):
            pfx = f"s{si}"
            stride = 1 if si == 0 else 2
            y = L.relu(bn(f"{pfx}.conv1", L.conv2d(v, f"{pfx}.conv1", x, k=3, stride=stride)))
            y = bn(f"{pfx}.conv2", L.conv2d(v, f"{pfx}.conv2", y, k=3))
            if cin_l != w:
                sc = bn(f"{pfx}.proj", L.conv2d(v, f"{pfx}.proj", x, k=1, stride=stride))
            else:
                sc = x
            x = L.relu(y + sc)
            cin_l = w
        x = L.global_avgpool(x)
        return L.dense(v, "fc", x)

    return Model("resnet8", b, apply, (hw, hw, in_ch), classes)


# ---------------------------------------------------------------------------
# mobilenet_tiny — inverted residual blocks, two scale placements
# ---------------------------------------------------------------------------

# (expansion, out_ch, stride)
MBV2_BLOCKS = [(2, 16, 1), (2, 24, 2), (2, 24, 1), (2, 32, 2), (2, 32, 1)]


def mobilenet_tiny(
    classes: int = 20,
    in_ch: int = 3,
    hw: int = 32,
    seed: int = 0,
    scale_mode: str = "project_only",  # or "full"
):
    assert scale_mode in ("project_only", "full")
    full = scale_mode == "full"
    b = L.Builder(seed)
    b.conv("stem", in_ch, 16, 3, scale=full)
    cin = 16
    for bi, (exp, out, _stride) in enumerate(MBV2_BLOCKS):
        pfx = f"b{bi}"
        mid = cin * exp
        b.conv(f"{pfx}.expand", cin, mid, 1, scale=full, bias=False)
        b.dwconv(f"{pfx}.dw", mid, 3, scale=full)
        # the paper's default placement: scale only the output (project)
        # conv of each inverted residual block
        b.conv(f"{pfx}.project", mid, out, 1, scale=True, bias=False)
        cin = out
    b.conv("head", cin, 64, 1, scale=full)
    b.dense("fc", 64, classes)

    def apply(v, x, train, new_state):
        def bn(name, t):
            return L.batchnorm(v, name, t, train=train, new_state=new_state)

        x = L.relu6(bn("stem", L.conv2d(v, "stem", x, k=3)))
        cin_l = 16
        for bi, (exp, out, stride) in enumerate(MBV2_BLOCKS):
            pfx = f"b{bi}"
            y = L.relu6(bn(f"{pfx}.expand", L.conv2d(v, f"{pfx}.expand", x, k=1)))
            y = L.relu6(bn(f"{pfx}.dw", L.dwconv2d(v, f"{pfx}.dw", y, k=3, stride=stride)))
            y = bn(f"{pfx}.project", L.conv2d(v, f"{pfx}.project", y, k=1))
            if stride == 1 and cin_l == out:
                y = y + x
            x = y
            cin_l = out
        x = L.relu6(bn("head", L.conv2d(v, "head", x, k=1)))
        x = L.global_avgpool(x)
        return L.dense(v, "fc", x)

    name = "mobilenet_tiny_full" if full else "mobilenet_tiny"
    return Model(name, b, apply, (hw, hw, in_ch), classes)


# ---------------------------------------------------------------------------
# vgg16_head — partial (classifier-only) vs end-to-end updates
# ---------------------------------------------------------------------------

VGG16_WIDTHS = [16, 16, 32, 32, 64, 64]
VGG16_POOL_AFTER = {1, 3, 5}


def vgg16_head(
    classes: int = 2,
    in_ch: int = 3,
    hw: int = 32,
    seed: int = 0,
    partial: bool = False,
):
    """VGG-style features + the paper's VGG16 classifier head (BatchNorm +
    two dense layers).  ``partial=True`` freezes the features: only the
    head's weights and its scale factors are trained/transmitted -- the
    paper's "partial update" with 258 scale factors analog (here
    128 + 64 + classes head scales)."""
    b = L.Builder(seed)
    t = not partial
    cin = in_ch
    for i, w in enumerate(VGG16_WIDTHS):
        b.conv(f"conv{i}", cin, w, 3, trainable=t)
        cin = w
    feat = VGG16_WIDTHS[-1] * 4 * 4  # 32 -> 3 pools -> 4x4
    b.batchnorm("headbn", feat, trainable=True)
    b.dense("fc1", feat, 128)
    b.dense("fc2", 128, classes)

    def apply(v, x, train, new_state):
        for i in range(len(VGG16_WIDTHS)):
            x = L.conv2d(v, f"conv{i}", x, k=3)
            x = L.batchnorm(v, f"conv{i}", x, train=train, new_state=new_state)
            x = L.relu(x)
            if i in VGG16_POOL_AFTER:
                x = L.maxpool(x)
        x = x.reshape(x.shape[0], -1)
        x = L.batchnorm(v, "headbn", x, train=train, new_state=new_state)
        x = L.relu(L.dense(v, "fc1", x))
        return L.dense(v, "fc2", x)

    name = "vgg16_partial" if partial else "vgg16_head"
    return Model(name, b, apply, (hw, hw, in_ch), classes)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY = {
    "tiny_cnn": tiny_cnn,
    "vgg11_thin": vgg11_thin,
    "resnet8": resnet8,
    "mobilenet_tiny": mobilenet_tiny,
    "mobilenet_tiny_full": lambda **kw: mobilenet_tiny(scale_mode="full", **kw),
    "vgg16_head": vgg16_head,
    "vgg16_partial": lambda **kw: vgg16_head(partial=True, **kw),
}


def build(name: str, **kw) -> Model:
    if name not in REGISTRY:
        raise KeyError(f"unknown model {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name](**kw)
