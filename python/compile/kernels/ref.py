"""Pure-jnp correctness oracles for the Pallas kernels.

These never appear in the AOT artifacts; they exist so pytest can assert
kernel == reference (allclose) across randomized shapes.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def scaled_matmul_ref(x, w, s):
    """(x @ w.T) * s  -- x: [B, K], w: [M, K], s: [M] -> [B, M]."""
    return jnp.matmul(x, w.T) * s[None, :]


def scaled_matmul_grads_ref(x, w, s, g):
    """Analytic VJP of scaled_matmul for the custom_vjp check."""
    gs = g * s[None, :]
    dx = gs @ w
    dw = gs.T @ x
    ds = jnp.sum(g * (x @ w.T), axis=0)
    return dx, dw, ds
