"""L1 Pallas kernels for the FSFL compute hot-spot (filter-scaled matmul)."""

from .scaled_matmul import (  # noqa: F401
    pallas_matmul,
    pallas_scaled_matmul,
    scaled_matmul,
)
