"""L1 Pallas kernels: tiled matmul with a fused per-output-channel scale.

The paper's compute hot-spot is the *filter-scaled* convolution / dense
layer: every output channel m of a conv (or output neuron of a dense
layer) is multiplied by a trainable scalar s_m (Eq. 4).  On GPU the paper
fuses this into the cuDNN epilogue; here we re-think it for the TPU
execution model:

  * convs are lowered to im2col + matmul so the MXU systolic array does
    the work (bfloat16/f32 dot, 128x128 tiles),
  * the scale multiply is fused into the *epilogue of the last K-step* of
    the tiled matmul, so the scaled output is produced on the way from
    VMEM back to HBM -- no second elementwise pass over the activation
    tensor,
  * BlockSpecs express the HBM<->VMEM schedule (the threadblock tiling of
    the CUDA version): out tile (bm, bn) revisited across the K grid
    dimension accumulates in place in VMEM.

All kernels are lowered with ``interpret=True`` -- the CPU PJRT plugin
cannot execute Mosaic custom-calls.  Numerics are validated against the
pure-jnp oracle in ``ref.py`` by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile. Small problems are padded up to one tile; the
# wrapper shrinks tiles for very small inputs so tests stay cheap.
DEFAULT_TILE = 128

# Tiling schedule (perf pass, EXPERIMENTS.md §Perf):
#   "mxu"    — 128x128 MXU tiles with a K accumulation loop: the schedule a
#              real TPU would run (bounded VMEM, systolic-array shaped).
#   "single" — one grid cell covering the whole (padded) problem: the only
#              fast configuration under interpret=True, where every extra
#              grid cell costs ~10 ms of emulation overhead (measured; see
#              EXPERIMENTS.md). Numerics are identical.
#   "auto"   — "single" (this build always executes via CPU interpret).
# The kernel BODY is the same either way; only the BlockSpecs change.
SCHEDULE = os.environ.get("FSFL_KERNEL_SCHEDULE", "auto")


def _resolve_schedule(schedule: str | None) -> str:
    s = schedule or SCHEDULE
    if s == "auto":
        return "single"
    assert s in ("mxu", "single"), f"unknown schedule {s!r}"
    return s


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest power-of-two tile <= preferred that keeps padding < 2x."""
    t = preferred
    while t > 8 and t >= 2 * dim:
        t //= 2
    return t


def _tiles(m: int, k: int, n: int, tile: int, schedule: str | None):
    """(bm, bk, bn) block shape for the resolved schedule."""
    if _resolve_schedule(schedule) == "single":
        # One grid cell covering the exact dims: interpret mode has no
        # alignment requirement, and skipping the pad avoids two full
        # operand copies per call.
        return m, k, n
    return _pick_tile(m, tile), _pick_tile(k, tile), _pick_tile(n, tile)


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref, *, nk: int):
    """o[i,j] = sum_k a[i,k] @ b[k,j], accumulated across the k grid dim.

    The output block (i, j) is revisited for every k step; in-place VMEM
    accumulation replaces the CUDA shared-memory accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _scaled_matmul_kernel(a_ref, b_ref, s_ref, o_ref, *, nk: int):
    """o[i,j] = (sum_k a[i,k] @ b[k,j]) * s[j] with the scale applied in the
    epilogue of the final k step (fused, single pass over the output)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * s_ref[...]


# Grid-less kernel bodies for the "single" schedule: the whole problem is
# one VMEM-resident block, so there is no program_id / revisit logic. The
# scale stays fused in the same store.
def _matmul_kernel_single(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _scaled_matmul_kernel_single(a_ref, b_ref, s_ref, o_ref):
    o_ref[...] = (
        jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32) * s_ref[...]
    )


# ---------------------------------------------------------------------------
# Padded pallas_call wrappers
# ---------------------------------------------------------------------------


def _pad2(x, rows: int, cols: int):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("tile", "schedule"))
def pallas_matmul(a, b, tile: int = DEFAULT_TILE, schedule: str | None = None):
    """Tiled ``a @ b`` for f32, a: [M, K], b: [K, N] -> [M, N]."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    if _resolve_schedule(schedule) == "single":
        return pl.pallas_call(
            _matmul_kernel_single,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(a.astype(jnp.float32), b.astype(jnp.float32))
    bm, bk, bn = _tiles(m, k, n, tile, schedule)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = _pad2(a.astype(jnp.float32), mp, kp)
    b_p = _pad2(b.astype(jnp.float32), kp, np_)
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("tile", "schedule"))
def pallas_scaled_matmul(a, b, s, tile: int = DEFAULT_TILE, schedule: str | None = None):
    """Tiled ``(a @ b) * s[None, :]`` -- the paper's Eq. (4) fused into the
    matmul epilogue.  a: [M, K], b: [K, N], s: [N] -> [M, N]."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    assert s.shape == (n,), f"scale shape {s.shape} != ({n},)"
    if _resolve_schedule(schedule) == "single":
        return pl.pallas_call(
            _scaled_matmul_kernel_single,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(a.astype(jnp.float32), b.astype(jnp.float32), s.astype(jnp.float32).reshape(1, n))
    bm, bk, bn = _tiles(m, k, n, tile, schedule)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = _pad2(a.astype(jnp.float32), mp, kp)
    b_p = _pad2(b.astype(jnp.float32), kp, np_)
    s_p = jnp.pad(s.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_scaled_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p, s_p)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Differentiable scaled matmul (custom VJP; fwd AND bwd run on Pallas)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def scaled_matmul(x, w, s):
    """``(x @ w.T) * s`` -- x: [B, K], w: [M, K] (filters as rows, im2col
    layout), s: [M] -> [B, M].

    Differentiable via custom_vjp; pallas_call has no automatic transpose
    rule, so the backward pass is expressed with the same tiled kernels:

        dx = (g * s) @ w          ds = sum_b g * (x @ w.T)
        dw = (g * s).T @ x
    """
    return pallas_scaled_matmul(x, w.T, s)


def _scaled_matmul_fwd(x, w, s):
    # Keep the unscaled product as a residual: ds = Σ_b g ⊙ raw needs it,
    # and saving it replaces a full recompute matmul in the backward pass
    # (≈ -25% of the train-step matmul count; EXPERIMENTS.md §Perf).
    raw = pallas_matmul(x, w.T)
    return raw * s[None, :], (x, w, s, raw)


def _scaled_matmul_bwd(res, g):
    x, w, s, raw = res
    gs = g * s[None, :]
    dx = pallas_matmul(gs, w)
    dw = pallas_matmul(gs.T, x)
    ds = jnp.sum(g * raw, axis=0)
    return dx, dw, ds


scaled_matmul.defvjp(_scaled_matmul_fwd, _scaled_matmul_bwd)
