"""Unit tests for step-function pieces: loss, metrics, optimizers, and
layer-level identities (scale folding)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile.steps import (
    ADAM_B1,
    ADAM_B2,
    ADAM_EPS,
    SGD_MOMENTUM,
    _adam,
    _sgd,
    count_correct,
    softmax_xent,
)


def test_softmax_xent_matches_manual():
    logits = jnp.array([[2.0, 1.0, 0.0], [0.0, 0.0, 0.0]], jnp.float32)
    y = jnp.array([[1, 0, 0], [0, 0, 1]], jnp.float32)
    got = float(softmax_xent(logits, y))
    p = np.exp(np.asarray(logits))
    p /= p.sum(-1, keepdims=True)
    want = -(np.log(p[0, 0]) + np.log(p[1, 2])) / 2
    assert abs(got - want) < 1e-6


def test_count_correct():
    logits = jnp.array([[0.1, 0.9], [0.8, 0.2], [0.3, 0.7]], jnp.float32)
    y = jnp.array([[0, 1], [0, 1], [0, 1]], jnp.float32)
    assert float(count_correct(logits, y)) == 2.0


def test_adam_single_step_reference():
    p = jnp.float32(1.0)
    g = jnp.float32(0.5)
    m = jnp.float32(0.0)
    v = jnp.float32(0.0)
    p1, m1, v1 = _adam(p, g, m, v, jnp.float32(1.0), jnp.float32(0.1))
    m_ref = (1 - ADAM_B1) * 0.5
    v_ref = (1 - ADAM_B2) * 0.25
    mhat = m_ref / (1 - ADAM_B1)
    vhat = v_ref / (1 - ADAM_B2)
    p_ref = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + ADAM_EPS)
    assert abs(float(p1) - p_ref) < 1e-6
    assert abs(float(m1) - m_ref) < 1e-9
    assert abs(float(v1) - v_ref) < 1e-9


def test_sgd_momentum_accumulates():
    p, m, v = jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0)
    g = jnp.float32(1.0)
    lr = jnp.float32(0.1)
    p, m, v = _sgd(p, g, m, v, jnp.float32(1.0), lr)
    assert abs(float(p) + 0.1) < 1e-7
    p, m, v = _sgd(p, g, m, v, jnp.float32(2.0), lr)
    # m = 0.9*1 + 1 = 1.9 → p = -0.1 - 0.19
    assert abs(float(m) - (SGD_MOMENTUM + 1.0)) < 1e-6
    assert abs(float(p) + 0.29) < 1e-6


def test_dwconv_scale_folding_equals_output_scaling():
    """Folding s into the depthwise kernel == scaling the output channel
    (Eq. 4 for 1-channel filters)."""
    rng = np.random.default_rng(0)
    c, k = 4, 3
    x = jnp.asarray(rng.normal(size=(2, 8, 8, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(c, k * k)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(c,)), jnp.float32)
    vals = {"dw.w": w, "dw.s": s}
    folded = L.dwconv2d(vals, "dw", x, k=k)
    vals_nos = {"dw.w": w}
    unscaled = L.dwconv2d(vals_nos, "dw", x, k=k)
    np.testing.assert_allclose(
        np.asarray(folded), np.asarray(unscaled * s), rtol=1e-5, atol=1e-5
    )


def test_conv2d_matches_lax_conv():
    """The im2col + Pallas path must equal a direct lax convolution."""
    from jax import lax

    rng = np.random.default_rng(1)
    cin, cout, k = 3, 5, 3
    x = jnp.asarray(rng.normal(size=(2, 6, 6, cin)), jnp.float32)
    w_rows = jnp.asarray(rng.normal(size=(cout, cin * k * k)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(cout,)), jnp.float32)
    vals = {"c.w": w_rows, "c.s": s}
    ours = L.conv2d(vals, "c", x, k=k)
    kern = jnp.transpose(w_rows.reshape(cout, cin, k, k), (2, 3, 1, 0))
    ref = (
        lax.conv_general_dilated(
            x, kern, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        * s
    )
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_batchnorm_train_vs_eval():
    rng = np.random.default_rng(2)
    c = 4
    x = jnp.asarray(rng.normal(loc=2.0, size=(8, 5, 5, c)), jnp.float32)
    vals = {
        "bn.gamma": jnp.ones(c),
        "bn.beta": jnp.zeros(c),
        "bn.mean": jnp.zeros(c),
        "bn.var": jnp.ones(c),
    }
    state = {}
    out_train = L.batchnorm(vals, "bn", x, train=True, new_state=state)
    # train mode: normalized to ~zero mean
    assert abs(float(jnp.mean(out_train))) < 1e-4
    # running stats moved toward the batch stats
    assert float(jnp.mean(state["bn.mean"])) > 0.1
    out_eval = L.batchnorm(vals, "bn", x, train=False, new_state={})
    # eval mode uses the (zero/one) running stats → mean stays ~2
    assert abs(float(jnp.mean(out_eval)) - 2.0) < 0.1
