"""AOT pipeline tests: HLO text emission, manifest/bundle contracts."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import zoo
from compile.aot import lower_variant, to_hlo_text
from compile.bundle import read_bundle, write_bundle


def test_bundle_roundtrip(tmp_path):
    p = str(tmp_path / "t.bin")
    tensors = [
        ("a.w", np.arange(6, dtype=np.float32).reshape(2, 3)),
        ("b", np.array([1.5, -2.5], np.float32)),
        ("scalar0", np.zeros((4,), np.float32)),
    ]
    write_bundle(p, tensors)
    back = read_bundle(p)
    assert len(back) == 3
    for (n0, a0), (n1, a1) in zip(tensors, back):
        assert n0 == n1
        np.testing.assert_array_equal(a0, a1)


def test_hlo_text_is_parseable_hlo():
    def fn(x):
        return (jnp.sum(x * 2.0),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lower_variant_emits_full_artifact_set(tmp_path):
    out = str(tmp_path / "tiny")
    man = lower_variant("tiny_cnn", out, batch=4, kwargs=dict(classes=10, hw=16), quiet=True)
    expected = [
        "train_step.hlo.txt",
        "train_step_sgd.hlo.txt",
        "scale_step_adam.hlo.txt",
        "scale_step_sgd.hlo.txt",
        "eval_step.hlo.txt",
        "predict_step.hlo.txt",
        "manifest.json",
        "manifest.tsv",
        "init.bin",
    ]
    for f in expected:
        assert os.path.exists(os.path.join(out, f)), f
    assert man["param_count"] > 0
    assert man["scale_count"] > 0
    # manifest.tsv tensor lines match the spec count
    tsv = open(os.path.join(out, "manifest.tsv")).read()
    n_tensor_lines = sum(1 for l in tsv.splitlines() if l.startswith("tensor\t"))
    assert n_tensor_lines == len(man["tensors"])
    # bundle order matches manifest order
    bundle = read_bundle(os.path.join(out, "init.bin"))
    assert [n for n, _ in bundle] == [t["name"] for t in man["tensors"]]


def test_wire_signature_counts(tmp_path):
    """Input/output arity of the lowered train step must match the rust
    marshalling convention: n + 2g + 4 inputs, n + 2g + 3 outputs."""
    out = str(tmp_path / "tiny2")
    man = lower_variant("tiny_cnn", out, batch=2, kwargs=dict(classes=10, hw=16), quiet=True)
    n = len(man["tensors"])
    g = len(man["groups"]["weight"])
    text = open(os.path.join(out, "train_step.hlo.txt")).read()
    header = text.splitlines()[0]
    assert "entry_computation_layout={(" in header
    sig = header.split("entry_computation_layout={(")[1]
    inputs, outputs = sig.split(")->")
    n_in = inputs.count("f32[")
    n_out = outputs.count("f32[")
    assert n_in == n + 2 * g + 4, f"{n_in} != {n + 2*g + 4}"
    assert n_out == n + 2 * g + 3, f"{n_out} != {n + 2*g + 3}"


def test_scale_groups_are_disjoint():
    model = zoo.build("tiny_cnn")
    groups = {}
    for sp in model.specs:
        groups.setdefault(sp.group, []).append(sp.name)
    all_names = [sp.name for sp in model.specs]
    covered = sum(len(v) for v in groups.values())
    assert covered == len(all_names)
