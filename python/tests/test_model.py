"""L2 model zoo tests: shapes, manifests, group semantics, learning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import zoo
from compile.steps import (
    group_indices,
    init_opt_state,
    make_eval_step,
    make_step,
    softmax_xent,
)

ALL_VARIANTS = sorted(zoo.REGISTRY)


def _batch(model, n=4, seed=0):
    rng = np.random.default_rng(seed)
    h, w, c = model.input_shape
    x = jnp.asarray(rng.normal(size=(n, h, w, c)), jnp.float32)
    labels = rng.integers(0, model.classes, size=n)
    y = jnp.asarray(np.eye(model.classes)[labels], jnp.float32)
    return x, y


def _params(model):
    return [jnp.asarray(model.values[sp.name]) for sp in model.specs]


@pytest.mark.parametrize("name", ALL_VARIANTS)
def test_forward_shapes(name):
    model = zoo.build(name)
    x, _ = _batch(model)
    vals = {sp.name: jnp.asarray(model.values[sp.name]) for sp in model.specs}
    state = {}
    logits = model.apply(vals, x, train=True, new_state=state)
    assert logits.shape == (4, model.classes)
    assert np.isfinite(np.asarray(logits)).all()
    # every BN layer reported updated running stats (steps.py persists only
    # the group=="state" subset; frozen layers' entries are ignored there)
    n_bn = sum(1 for sp in model.specs if sp.kind == "bn_mean")
    assert len(state) == 2 * n_bn


@pytest.mark.parametrize("name", ALL_VARIANTS)
def test_manifest_structure(name):
    model = zoo.build(name)
    names = [sp.name for sp in model.specs]
    assert len(names) == len(set(names)), "duplicate tensor names"
    groups = {sp.group for sp in model.specs}
    assert groups <= {"weight", "scale", "state", "frozen"}
    for sp in model.specs:
        if sp.kind == "scale":
            assert sp.scale_for in names
            widx = names.index(sp.scale_for)
            # scale length == number of filter rows it scales
            assert sp.shape[0] == model.specs[widx].shape[0]
        if sp.kind in ("conv_w", "dense_w", "dw_conv_w"):
            assert len(sp.shape) == 2, "row layout required"
            assert sp.out_ch == sp.shape[0]
        # initial scale values are exactly 1 (Algorithm 1 init)
        if sp.kind == "scale":
            assert np.all(model.values[sp.name] == 1.0)


def test_vgg11_matches_paper_table1():
    """Paper Table 1: VGG11_CIFAR10 has 0.8M params and 1,002 extra
    scaling parameters."""
    model = zoo.build("vgg11_thin")
    total = sum(int(np.prod(sp.shape)) for sp in model.specs)
    scales = sum(
        int(np.prod(sp.shape)) for sp in model.specs if sp.group == "scale"
    )
    assert scales == 1002
    assert 0.7e6 < total < 1.0e6


def test_partial_variant_freezes_features():
    full = zoo.build("vgg16_head")
    part = zoo.build("vgg16_partial")
    # same tensor set, different groups
    assert [sp.name for sp in full.specs] == [sp.name for sp in part.specs]
    fw = {sp.name for sp in part.specs if sp.group in ("weight", "scale", "state")}
    assert all(not n.startswith("conv") for n in fw)
    # paper: only a couple hundred scale factors in the partial head
    n_scales = sum(
        int(np.prod(sp.shape)) for sp in part.specs if sp.group == "scale"
    )
    assert 0 < n_scales < 300


def test_mobilenet_scale_placements():
    proj = zoo.build("mobilenet_tiny")
    full = zoo.build("mobilenet_tiny_full")
    n_proj = sum(1 for sp in proj.specs if sp.kind == "scale")
    n_full = sum(1 for sp in full.specs if sp.kind == "scale")
    assert n_full > n_proj
    proj_layers = {sp.layer for sp in proj.specs if sp.kind == "scale"}
    assert all(".project" in l or l == "fc" for l in proj_layers)


def test_train_step_freezes_scales_and_updates_weights():
    model = zoo.build("tiny_cnn")
    step = make_step(model, group="weight", opt="adam", train_bn=True)
    params = _params(model)
    g = step.group_size
    ms = [jnp.zeros(model.specs[i].shape) for i in step.group_indices]
    vs = [jnp.zeros(model.specs[i].shape) for i in step.group_indices]
    x, y = _batch(model, n=8)
    out = step(params, ms, vs, jnp.float32(0.0), jnp.float32(1e-2), x, y)
    n = len(params)
    new_params = out[:n]
    scale_idx = group_indices(model.specs, "scale")
    for i in scale_idx:
        np.testing.assert_array_equal(np.asarray(new_params[i]), np.asarray(params[i]))
    widx = group_indices(model.specs, "weight")
    changed = sum(
        not np.array_equal(np.asarray(new_params[i]), np.asarray(params[i]))
        for i in widx
    )
    assert changed > 0
    t_out, loss, correct = out[-3], out[-2], out[-1]
    assert float(t_out) == 1.0
    assert np.isfinite(float(loss))
    assert 0 <= float(correct) <= 8


def test_scale_step_freezes_weights_and_bn_state():
    model = zoo.build("tiny_cnn")
    step = make_step(model, group="scale", opt="adam", train_bn=False)
    params = _params(model)
    ms = [jnp.zeros(model.specs[i].shape) for i in step.group_indices]
    vs = [jnp.zeros(model.specs[i].shape) for i in step.group_indices]
    x, y = _batch(model, n=8)
    out = step(params, ms, vs, jnp.float32(0.0), jnp.float32(1e-1), x, y)
    n = len(params)
    new_params = out[:n]
    for i in group_indices(model.specs, "weight") + group_indices(
        model.specs, "state"
    ):
        np.testing.assert_array_equal(np.asarray(new_params[i]), np.asarray(params[i]))
    changed = sum(
        not np.array_equal(np.asarray(new_params[i]), np.asarray(params[i]))
        for i in group_indices(model.specs, "scale")
    )
    assert changed > 0


@pytest.mark.parametrize("opt", ["adam", "sgd"])
def test_training_reduces_loss(opt):
    model = zoo.build("tiny_cnn")
    step = jax.jit(make_step(model, group="weight", opt=opt, train_bn=True))
    params = _params(model)
    gi = group_indices(model.specs, "weight")
    ms = [jnp.zeros(model.specs[i].shape) for i in gi]
    vs = [jnp.zeros(model.specs[i].shape) for i in gi]
    x, y = _batch(model, n=16, seed=7)
    t = jnp.float32(0.0)
    lr = jnp.float32(5e-3 if opt == "adam" else 5e-2)
    losses = []
    n = len(params)
    g = len(ms)
    for _ in range(30):
        out = step(params, ms, vs, t, lr, x, y)
        params = list(out[:n])
        ms, vs = list(out[n : n + g]), list(out[n + g : n + 2 * g])
        t = out[n + 2 * g]
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_scale_training_can_reduce_loss():
    """Macro-training: optimizing only S moves the loss (paper Sec. 4)."""
    model = zoo.build("tiny_cnn")
    step = jax.jit(make_step(model, group="scale", opt="adam", train_bn=False))
    params = _params(model)
    gi = group_indices(model.specs, "scale")
    ms = [jnp.zeros(model.specs[i].shape) for i in gi]
    vs = [jnp.zeros(model.specs[i].shape) for i in gi]
    x, y = _batch(model, n=16, seed=3)
    t = jnp.float32(0.0)
    n, g = len(params), len(ms)
    losses = []
    for _ in range(20):
        out = step(params, ms, vs, t, jnp.float32(5e-2), x, y)
        params = list(out[:n])
        ms, vs = list(out[n : n + g]), list(out[n + g : n + 2 * g])
        t = out[n + 2 * g]
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0], losses


def test_eval_step_deterministic():
    model = zoo.build("tiny_cnn")
    ev = jax.jit(make_eval_step(model))
    params = _params(model)
    x, y = _batch(model, n=8)
    l1, c1 = ev(params, x, y)
    l2, c2 = ev(params, x, y)
    assert float(l1) == float(l2) and float(c1) == float(c2)


def test_unit_scales_are_identity():
    """S=1 must not change the computational graph output (Appendix A)."""
    model = zoo.build("tiny_cnn")
    vals = {sp.name: jnp.asarray(model.values[sp.name]) for sp in model.specs}
    x, _ = _batch(model)
    base = model.apply(dict(vals), x, train=False, new_state={})
    doubled = dict(vals)
    for sp in model.specs:
        if sp.kind == "scale":
            doubled[sp.name] = vals[sp.name] * 2.0
    out2 = model.apply(doubled, x, train=False, new_state={})
    assert not np.allclose(np.asarray(base), np.asarray(out2))
