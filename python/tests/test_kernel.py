"""Kernel vs ref allclose -- the CORE correctness signal for L1.

hypothesis sweeps shapes; every property asserts the Pallas kernel
against the pure-jnp oracle in compile.kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_matmul, pallas_scaled_matmul, scaled_matmul
from compile.kernels.ref import (
    matmul_ref,
    scaled_matmul_grads_ref,
    scaled_matmul_ref,
)

DIMS = st.integers(min_value=1, max_value=200)
SMALL = st.integers(min_value=1, max_value=48)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _tol(k):
    # f32 dot accumulation error grows with the contraction length.
    return dict(rtol=1e-4, atol=1e-4 * max(1.0, k / 16.0))


@pytest.mark.parametrize("schedule", ["mxu", "single"])
@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_pallas_matmul_matches_ref(schedule, m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(
        pallas_matmul(a, b, schedule=schedule), matmul_ref(a, b), **_tol(k)
    )


@pytest.mark.parametrize("schedule", ["mxu", "single"])
@settings(max_examples=20, deadline=None)
@given(b=DIMS, k=DIMS, m=DIMS, seed=st.integers(0, 2**31 - 1))
def test_pallas_scaled_matmul_matches_ref(schedule, b, k, m, seed):
    rng = np.random.default_rng(seed)
    x, w, s = _rand(rng, b, k), _rand(rng, m, k), _rand(rng, m)
    np.testing.assert_allclose(
        pallas_scaled_matmul(x, w.T, s, schedule=schedule),
        scaled_matmul_ref(x, w, s),
        **_tol(k),
    )


def test_schedules_agree_bitwise_vs_ref_tolerance():
    """MXU-tiled and single-block schedules compute the same function."""
    rng = np.random.default_rng(0)
    x, w, s = _rand(rng, 150, 70), _rand(rng, 90, 70), _rand(rng, 90)
    a = pallas_scaled_matmul(x, w.T, s, schedule="mxu")
    b = pallas_scaled_matmul(x, w.T, s, schedule="single")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(b=DIMS, k=DIMS, m=DIMS, seed=st.integers(0, 2**31 - 1))
def test_scaled_matmul_matches_ref(b, k, m, seed):
    rng = np.random.default_rng(seed)
    x, w, s = _rand(rng, b, k), _rand(rng, m, k), _rand(rng, m)
    np.testing.assert_allclose(
        scaled_matmul(x, w, s), scaled_matmul_ref(x, w, s), **_tol(k)
    )


@settings(max_examples=15, deadline=None)
@given(b=SMALL, k=SMALL, m=SMALL, seed=st.integers(0, 2**31 - 1))
def test_scaled_matmul_custom_vjp_matches_analytic(b, k, m, seed):
    rng = np.random.default_rng(seed)
    x, w, s = _rand(rng, b, k), _rand(rng, m, k), _rand(rng, m)
    g = _rand(rng, b, m)
    out, vjp = jax.vjp(scaled_matmul, x, w, s)
    dx, dw, ds = vjp(g)
    rdx, rdw, rds = scaled_matmul_grads_ref(x, w, s, g)
    np.testing.assert_allclose(dx, rdx, **_tol(m))
    np.testing.assert_allclose(dw, rdw, **_tol(b))
    np.testing.assert_allclose(ds, rds, **_tol(b * k))


@settings(max_examples=10, deadline=None)
@given(b=SMALL, k=SMALL, m=SMALL, seed=st.integers(0, 2**31 - 1))
def test_scaled_matmul_vjp_matches_jax_autodiff_of_ref(b, k, m, seed):
    """custom_vjp must agree with jax's own autodiff of the oracle."""
    rng = np.random.default_rng(seed)
    x, w, s = _rand(rng, b, k), _rand(rng, m, k), _rand(rng, m)

    def f_kernel(x, w, s):
        return jnp.sum(jnp.sin(scaled_matmul(x, w, s)))

    def f_ref(x, w, s):
        return jnp.sum(jnp.sin(scaled_matmul_ref(x, w, s)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, s)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, s)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-3, atol=1e-3)


def test_scale_of_ones_is_plain_matmul():
    rng = np.random.default_rng(0)
    x, w = _rand(rng, 17, 33), _rand(rng, 9, 33)
    s = jnp.ones((9,), jnp.float32)
    np.testing.assert_allclose(
        scaled_matmul(x, w, s), pallas_matmul(x, w.T), rtol=1e-5, atol=1e-5
    )


def test_zero_scale_zeroes_output_column():
    rng = np.random.default_rng(1)
    x, w = _rand(rng, 8, 16), _rand(rng, 4, 16)
    s = jnp.array([1.0, 0.0, 2.0, 0.0], jnp.float32)
    out = np.asarray(scaled_matmul(x, w, s))
    assert np.all(out[:, 1] == 0.0) and np.all(out[:, 3] == 0.0)


def test_tile_boundary_shapes():
    """Exact multiples of the 128 tile and off-by-one both work."""
    rng = np.random.default_rng(2)
    for b, k, m in [(128, 128, 128), (129, 127, 128), (256, 64, 130), (1, 1, 1)]:
        x, w, s = _rand(rng, b, k), _rand(rng, m, k), _rand(rng, m)
        np.testing.assert_allclose(
            scaled_matmul(x, w, s), scaled_matmul_ref(x, w, s), **_tol(k)
        )


def test_jit_of_grad_composes():
    rng = np.random.default_rng(3)
    x, w, s = _rand(rng, 12, 20), _rand(rng, 7, 20), _rand(rng, 7)

    @jax.jit
    def loss(x, w, s):
        return jnp.mean(scaled_matmul(x, w, s) ** 2)

    g = jax.jit(jax.grad(loss, argnums=(1, 2)))(x, w, s)
    assert all(np.isfinite(np.asarray(t)).all() for t in g)
